//! The real inference engine: drives the AOT artifacts through the FreeKV
//! data path — per-layer QKV, fine-grained correction, gathered-page
//! attention, append/offload, and speculative selection+recall for the
//! next step. Python is never touched; everything runs over the PJRT CPU
//! client against `artifacts/`.
//!
//! Speculative recall is dispatched to the background worker of
//! `transfer::pipeline` (when `FreeKvParams::overlap` is set): layer
//! *l*'s next-step recall runs while this thread computes layers
//! *l+1..L* and the step's logits, and is drained at the next step's
//! correction check. Gather is incremental: each sequence keeps
//! per-layer persistent batch-lane tensors that only dirty slots are
//! rewritten into.
//!
//! Artifact execution itself is dispatched through `runtime::executor`
//! when `FreeKvParams::exec_workers > 0`: the decode step is factored
//! into explicit submit/join phases over a [`Lane`] (one microbatch), so
//! selection scoring runs on a pool worker while this thread drains the
//! recall pipeline. [`Engine::decode_step_lanes`] generalizes this to N
//! microbatch lanes: a bucket-aware planner splits the joint batch into
//! the lane widths that minimize padded artifact compute (up to
//! `FreeKvParams::max_lanes` in flight), and an in-engine lane scheduler
//! drives each lane's submit/join state machine, advancing whichever
//! lane's pool ticket completes next — so one lane's host-side work
//! (gather, correction, page bookkeeping) overlaps the others' QKV /
//! attention execution with no fixed alternation. Prefill rides the
//! same pool as chunked jobs ([`Engine::prefill_begin`]): a long prompt
//! is embedded, layered, logits-ed, and speculation-seeded one artifact
//! at a time, interleaving with in-flight decode lanes instead of
//! stalling the engine thread. With `exec_workers == 0` every phase
//! executes inline in the same order — the serial-dispatch ablation —
//! and outputs are bit-identical either way.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::{FreeKvParams, ModelConfig};
use crate::kvcache::alloc::worst_case_pages;
use crate::kvcache::{AdmitDecision, KvPoolStats, Layout, PageAllocator, RequestKv};
use crate::policies::freekv::{correction_check, SpecState};
use crate::runtime::{ExecDone, ExecJob, ExecTicket, ExecutorPool, HostTensor, Runtime};
use crate::transfer::{RecallJob, RecallPipeline, TransferEngine};
use crate::util::fault::FaultPlan;
use crate::util::rng::Rng;

/// Distinguishes Sequence objects even when callers reuse request ids
/// (the recall pipeline keys in-flight work by this uid).
static SEQ_UID: AtomicU64 = AtomicU64::new(1);

/// Wall-time breakdown of the real pipeline (per engine, cumulative).
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    /// Wall seconds spent in prefill.
    pub prefill_secs: f64,
    /// Wall seconds spent in decode steps.
    pub decode_secs: f64,
    /// Wall seconds in QKV projection.
    pub qkv_secs: f64,
    /// Wall seconds in attention + FFN execution.
    pub attn_secs: f64,
    /// Wall seconds the engine thread blocked on selection scoring.
    pub select_secs: f64,
    /// Wall seconds gathering budget-cache slabs for attention.
    pub gather_secs: f64,
    /// Wall seconds in recall on the engine thread (exposed + joins).
    pub recall_secs: f64,
    /// Wall seconds in the logits head + sampling.
    pub logits_secs: f64,
    /// Recall wall time spent on the background worker (off the decode
    /// critical path).
    pub recall_hidden_secs: f64,
    /// Recall latency the decode thread actually waited for: blocking
    /// correction recalls, serial-mode speculative recall, and drain
    /// waits on the worker.
    pub recall_exposed_secs: f64,
    /// Speculative-recall jobs handed to the background worker.
    pub recall_jobs: u64,
    /// Peak number of jobs simultaneously in flight on the worker.
    pub max_queue_depth: u64,
    /// Artifact executions dispatched to the executor pool (0 under
    /// serial in-thread dispatch).
    pub exec_jobs: u64,
    /// Selection-scoring worker time hidden behind engine-thread work
    /// (`select_secs` counts only the time the engine blocked joining).
    pub select_hidden_secs: f64,
    /// Decode invocations that pipelined >= 2 microbatch lanes through
    /// the lane scheduler.
    pub lane_sets: u64,
    /// Peak microbatch lanes concurrently in flight on the scheduler.
    pub max_lanes_inflight: u64,
    /// Pooled prefill chunks (embed / layer / logits / seed jobs)
    /// completed on executor workers.
    pub prefill_chunks: u64,
    /// Prefill chunks that completed while decode work (a joint step or
    /// a lane set) was in flight — the proof that prefill no longer
    /// stalls decode.
    pub prefill_overlap_chunks: u64,
    /// XLA executable compiles across the engine runtime and every pool
    /// worker (route-aware warm-up keeps this near one compile per
    /// artifact per *eligible* runtime instead of per worker).
    pub exec_compiles: u64,
    /// Weight-blob device uploads across the engine runtime and pool
    /// workers; bounded by `weight_workers + 1`, not the pool size.
    pub weight_uploads: u64,
    /// Distinct CPU pool pages allocated across the shared KV allocator
    /// (shared pages counted once, process-wide). Gauge, synced per step.
    pub kv_pages_used: u64,
    /// Pool pages currently aliased by two or more requests.
    pub kv_pages_shared: u64,
    /// Offloads satisfied by prefix sharing instead of a page write.
    pub kv_prefix_hits: u64,
    /// Allocator-charged bytes: distinct CPU pool pages + GPU-ledger
    /// bytes of live requests.
    pub kv_bytes_used: u64,
    // ---- persistent prefix-cache gauges (PR 8) ----
    /// Pool pages currently in the retained tier: committed prefix
    /// pages with zero live references, pinned by the cache instead of
    /// freed. Gauge, synced per step.
    pub kv_pages_retained: u64,
    /// Prefix adoptions that revived a page from the retained tier
    /// (the sharing request had already fully retired).
    pub kv_retained_hits: u64,
    /// Retained pages reclaimed under pool pressure or the retention
    /// cap (LRU-with-popularity victim order).
    pub kv_retained_evictions: u64,
    /// Pool-write bytes avoided by prefix sharing, resident and
    /// retained combined (`prefix_hits x page payload bytes`).
    pub kv_bytes_saved: u64,
    /// Prompt tokens whose KV pool pages were adopted from a cached
    /// prefix instead of re-offloaded during prefill.
    pub prefill_tokens_saved: u64,
    // ---- allocator lock-contention gauges (PR 9) ----
    /// Allocator shard-lock acquisitions that found the lock held and
    /// blocked (cumulative across all per-layer slab locks; the
    /// engine-vs-recall-worker serialization the sharding removes).
    pub kv_shard_lock_waits: u64,
    /// Total seconds spent blocked on allocator shard locks.
    pub kv_shard_lock_wait_secs: f64,
    /// Allocator metadata-lock acquisitions that blocked (prefix
    /// registry / retained tier / ledgers).
    pub kv_meta_lock_waits: u64,
    /// Total seconds spent blocked on the allocator metadata lock.
    pub kv_meta_lock_wait_secs: f64,
    /// Decode steps executed.
    pub steps: u64,
    /// Decode steps that carried ≥ 2 sequences (continuous batching
    /// actually interleaving concurrent requests).
    pub batched_steps: u64,
    /// Largest number of sequences decoded together in one step.
    pub max_batch_lanes: u64,
    /// Prefills executed.
    pub prefills: u64,
    /// Correction recalls triggered (similarity below tau).
    pub corrections: u64,
    /// Correction-trigger checks performed.
    pub correction_checks: u64,
    /// Pages moved CPU→GPU by selection/correction recall.
    pub recalled_pages: u64,
    /// Steps where the speculative selection needed no correction.
    pub speculative_hits: u64,
    // ---- fault-domain / degradation gauges (PR 6) ----
    /// Speculative recalls that fell back to the serial (exposed) path
    /// because the recall worker died or aborted a job. Non-zero means
    /// the overlap pipeline is disabled for the rest of this engine's
    /// life (degraded mode).
    pub recall_fallbacks: u64,
    /// Executor workers currently dead (gauge, synced per step).
    pub exec_dead_workers: u64,
    /// Executor workers respawned after dying.
    pub exec_respawns: u64,
    /// Exec job attempts that failed once and were retried.
    pub exec_retries: u64,
    /// Pooled dispatches that ran inline because no live (or revivable)
    /// worker could take the job.
    pub exec_inline_fallbacks: u64,
    /// Faults injected by the active `FaultPlan` (0 in production).
    pub faults_injected: u64,
}

impl EngineStats {
    /// Fraction of correction checks that triggered a correction.
    pub fn correction_rate(&self) -> f64 {
        if self.correction_checks == 0 {
            0.0
        } else {
            self.corrections as f64 / self.correction_checks as f64
        }
    }

    /// Fold the shared KV pool gauges into the stats — the one mapping
    /// used by every backend, so `/stats` cannot diverge between them.
    pub fn sync_kv(&mut self, kv: &crate::kvcache::KvPoolStats) {
        self.kv_pages_used = kv.pages_used;
        self.kv_pages_shared = kv.pages_shared;
        self.kv_prefix_hits = kv.prefix_hits;
        self.kv_bytes_used = kv.cpu_bytes_used + kv.gpu_bytes_used;
        self.kv_pages_retained = kv.pages_retained;
        self.kv_retained_hits = kv.retained_hits;
        self.kv_retained_evictions = kv.retained_evictions;
        self.kv_bytes_saved = kv.bytes_saved;
        self.kv_shard_lock_waits = kv.shard_lock_waits;
        self.kv_shard_lock_wait_secs = kv.shard_lock_wait_secs;
        self.kv_meta_lock_waits = kv.meta_lock_waits;
        self.kv_meta_lock_wait_secs = kv.meta_lock_wait_secs;
    }

    /// Fraction of recall wall time hidden behind compute (0 when every
    /// transfer blocked the decode thread).
    pub fn recall_hidden_fraction(&self) -> f64 {
        let total = self.recall_hidden_secs + self.recall_exposed_secs;
        if total <= 0.0 {
            0.0
        } else {
            self.recall_hidden_secs / total
        }
    }

    /// Is this engine running on a degradation ladder rung — serving,
    /// but with a helper thread lost or routed around? Feeds the
    /// `Ok`/`Degraded` health state on `/healthz`.
    pub fn degraded(&self) -> bool {
        self.recall_fallbacks > 0 || self.exec_dead_workers > 0 || self.exec_inline_fallbacks > 0
    }
}

/// The engine interface the scheduler drives. `Engine` is the real
/// artifact-backed implementation; `coordinator::sim_backend::SimBackend`
/// is an artifact-free stand-in for tests, benches, and `--sim` serving.
///
/// Contract: `prefill` fills the sequence's KV state for the prompt and
/// returns next-token logits (the scheduler samples the first token);
/// `decode_step` appends exactly one sampled token to every sequence in
/// the batch; `retire_sequence` releases any engine-held resources of a
/// sequence leaving mid-generation (the sequence's KV memory itself is
/// freed when the `Sequence` drops).
pub trait Backend {
    fn model(&self) -> &ModelConfig;

    fn new_sequence(
        &self,
        id: u64,
        prompt: Vec<i32>,
        max_new: usize,
        sample: SampleParams,
    ) -> Sequence {
        Sequence::new(id, self.model(), prompt, max_new, Layout::Hnd, sample)
    }

    fn prefill(&mut self, seq: &mut Sequence) -> Result<Vec<f32>>;

    /// Hand a sequence to the backend for prefill. A backend with an
    /// executor pool may run it asynchronously in chunks — then this
    /// returns `None` and the completed prefill surfaces later from
    /// [`Backend::prefill_poll`] / [`Backend::prefill_wait`]. The
    /// default completes synchronously and returns the result at once.
    fn prefill_begin(&mut self, mut seq: Sequence) -> Option<PrefillDone> {
        let result = self.prefill(&mut seq);
        Some(PrefillDone { seq, result })
    }

    /// Non-blocking: advance any in-flight asynchronous prefills and
    /// return the ones that completed (possibly failed).
    fn prefill_poll(&mut self) -> Vec<PrefillDone> {
        Vec::new()
    }

    /// Block until at least one in-flight asynchronous prefill
    /// completes; returns the completed set (empty when none in flight).
    fn prefill_wait(&mut self) -> Vec<PrefillDone> {
        Vec::new()
    }

    /// Asynchronous prefills currently in flight.
    fn prefills_inflight(&self) -> usize {
        0
    }

    /// Abandon an in-flight asynchronous prefill, returning the
    /// sequence so the caller can release its KV state. `None` when
    /// `id` is not prefilling.
    fn prefill_cancel(&mut self, _id: u64) -> Option<Sequence> {
        None
    }

    fn decode_step(&mut self, seqs: &mut [&mut Sequence]) -> Result<()>;

    /// Decode several disjoint microbatch lanes "in flight together",
    /// appending exactly one token to every sequence of every lane —
    /// equivalent in outputs to one `decode_step` per lane. The default
    /// runs the lanes back to back (correct for any backend) with
    /// per-lane error containment: a failing lane does not stop the
    /// remaining lanes from taking their step (its own sequences simply
    /// don't advance this step), and the first error is returned once
    /// every lane has been driven. The real [`Engine`] overrides this
    /// with a bucket-aware lane scheduler that pipelines the lanes
    /// across its executor pool; the caller's partition is advisory —
    /// the engine may repartition (or merge) when the compiled buckets
    /// make the given split wasteful.
    fn decode_step_lanes(&mut self, lanes: &mut [Vec<&mut Sequence>]) -> Result<()> {
        contain_lanes(lanes.iter_mut().filter(|l| !l.is_empty()), |lane| self.decode_step(lane))
    }

    /// Mid-flight retirement hook: reclaim in-flight transfer state so a
    /// cancelled sequence strands nothing on background workers.
    fn retire_sequence(&mut self, _seq: &mut Sequence) {}

    /// Capacity-aware admission: charge the request's worst-case KV
    /// page footprint against the shared pool before it starts.
    /// `Admit` reserves the footprint (pair with
    /// [`Backend::kv_release`]); `Wait` asks the scheduler to keep the
    /// request queued until running requests free pages; `Never` means
    /// the footprint exceeds the whole pool. The default admits
    /// everything (no pool limit).
    fn kv_admit(&mut self, _id: u64, _prompt_tokens: usize, _max_new: usize) -> AdmitDecision {
        AdmitDecision::Admit
    }

    /// Release the admission reservation taken by [`Backend::kv_admit`]
    /// (idempotent; called on finish, cancel, and prefill failure).
    fn kv_release(&mut self, _id: u64) {}

    /// Live gauges of the shared KV pool (pages used/shared, prefix
    /// hits, allocator-charged bytes). Zeros for backends without one.
    fn kv_stats(&self) -> KvPoolStats {
        KvPoolStats::default()
    }

    fn stats(&self) -> &EngineStats;
}

/// Sampling parameters.
#[derive(Debug, Clone)]
pub struct SampleParams {
    /// Softmax temperature; 0 = greedy argmax.
    pub temperature: f32,
    /// Nucleus (top-p) truncation threshold.
    pub top_p: f32,
    /// Per-request sampling seed.
    pub seed: u64,
}

impl SampleParams {
    /// Deterministic greedy decoding (temperature 0).
    pub fn greedy() -> SampleParams {
        SampleParams { temperature: 0.0, top_p: 1.0, seed: 0 }
    }
}

/// A prefill the backend finished (synchronously or asynchronously):
/// the sequence comes back with either its next-token logits or the
/// per-request failure.
pub struct PrefillDone {
    /// The sequence whose prefill completed.
    pub seq: Sequence,
    /// Next-token logits on success, per-request error otherwise.
    pub result: Result<Vec<f32>>,
}

/// Per-layer persistent gather destination (one batch lane).
struct GatherBuf {
    k: Vec<f32>,
    v: Vec<f32>,
    valid: Vec<f32>,
}

/// One in-flight sequence (request) with its KV state.
pub struct Sequence {
    /// Caller-assigned request id (may repeat across sessions).
    pub id: u64,
    uid: u64,
    /// Prompt tokens followed by generated tokens.
    pub tokens: Vec<i32>,
    /// Length of the prompt portion of `tokens`.
    pub prompt_len: usize,
    /// Generation budget.
    pub max_new_tokens: usize,
    /// All KV-cache state across layers.
    pub kv: RequestKv,
    /// Per-request transfer engine (offload/recall counters).
    pub xfer: TransferEngine,
    /// Sampling parameters.
    pub sample: SampleParams,
    /// Sampling RNG (seeded from `sample.seed` and `id`).
    pub rng: Rng,
    /// Set when generation hit EOS or was finished externally.
    pub finished: bool,
    /// EOS token that ended generation, if any.
    pub eos: Option<i32>,
    spec: Vec<SpecState>,
    /// per-layer persistent gather lanes (incrementally maintained).
    gather: Vec<GatherBuf>,
}

impl Sequence {
    /// Sequence over a private, unbounded page allocator (standalone
    /// tools and tests); serving engines share one allocator across
    /// sequences via [`Sequence::with_alloc`].
    pub fn new(
        id: u64,
        cfg: &ModelConfig,
        prompt: Vec<i32>,
        max_new: usize,
        layout: Layout,
        sample: SampleParams,
    ) -> Sequence {
        let alloc = PageAllocator::for_model(cfg, 0, false);
        Sequence::with_alloc(id, cfg, prompt, max_new, layout, sample, alloc)
    }

    /// Sequence drawing CPU pages from a shared allocator.
    pub fn with_alloc(
        id: u64,
        cfg: &ModelConfig,
        prompt: Vec<i32>,
        max_new: usize,
        layout: Layout,
        sample: SampleParams,
        alloc: Arc<PageAllocator>,
    ) -> Sequence {
        let s = cfg.budget_slots();
        Sequence {
            id,
            uid: SEQ_UID.fetch_add(1, Ordering::Relaxed),
            prompt_len: prompt.len(),
            tokens: prompt,
            max_new_tokens: max_new,
            kv: RequestKv::with_alloc(cfg, layout, alloc),
            xfer: TransferEngine::new(cfg.page_size, cfg.d_head, true),
            rng: Rng::new(sample.seed ^ id.wrapping_mul(0x9E3779B97F4A7C15)),
            sample,
            finished: false,
            eos: None,
            spec: (0..cfg.n_layers).map(|_| SpecState::new(cfg.n_qo, cfg.n_kv, cfg.d_head)).collect(),
            gather: (0..cfg.n_layers)
                .map(|_| GatherBuf {
                    k: vec![0.0; cfg.n_kv * s * cfg.d_head],
                    v: vec![0.0; cfg.n_kv * s * cfg.d_head],
                    valid: vec![0.0; cfg.n_kv * s],
                })
                .collect(),
        }
    }

    /// Tokens generated so far (excludes the prompt).
    pub fn generated(&self) -> &[i32] {
        &self.tokens[self.prompt_len..]
    }

    /// Absolute sequence position (tokens with KV appended).
    pub fn pos(&self) -> usize {
        self.kv.len()
    }

    /// Whether generation finished (EOS or budget exhausted).
    pub fn done(&self) -> bool {
        self.finished || self.generated().len() >= self.max_new_tokens
    }
}

/// Reused artifact-input scratch for batched selection (the smin/smax
/// planes are the largest per-step host allocations; rebuilding them
/// every layer/step is pure waste). Kept in a small free-list on the
/// engine: pooled dispatch moves the tensors into the executor job and
/// gets them back with the completion, and paired microbatches need two
/// in rotation.
struct SelScratch {
    bucket: usize,
    /// [q, smin, smax, mask] in the select artifact's argument order.
    args: Vec<HostTensor>,
}

/// An artifact execution in flight: either already done (serial
/// in-thread dispatch, or a pool ticket the lane scheduler folded after
/// observing it complete) or a ticket on the executor pool. Both hand
/// the input tensors back so scratch buffers survive the round trip.
/// `waited_secs` is what this thread actually blocked: equal to
/// `busy_secs` for inline execution, ~0 for a polled completion.
enum Pending {
    Ready {
        outputs: Vec<HostTensor>,
        inputs: Vec<HostTensor>,
        busy_secs: f64,
        waited_secs: f64,
    },
    Ticket(ExecTicket),
}

/// Per-microbatch decode state threaded through the lane phases. Holds
/// the mutable borrow of its sequences plus the tensors that flow
/// between phases; at most one artifact execution is pending per lane.
struct Lane<'a, 'b> {
    seqs: &'a mut [&'b mut Sequence],
    /// live sequences (<= bucket; the rest is padding).
    n: usize,
    bucket: usize,
    /// hidden state entering the next artifact.
    h: Option<HostTensor>,
    /// position tensor, reused across layers.
    pos_t: Option<HostTensor>,
    pending: Option<Pending>,
    q_all: Vec<f32>,
    k_new: Vec<f32>,
    v_new: Vec<f32>,
    /// (q, k_new, v_new) tensors held for the attention args.
    qkv_t: Option<(HostTensor, HostTensor, HostTensor)>,
    /// selected pages per (sequence, kv head), post mask filter.
    sel_pages: Vec<Vec<Vec<usize>>>,
    /// route *every* artifact of this lane through the pool (lane-set
    /// mode, where the other lanes' host work overlaps). Single-lane
    /// decode pools only selection — the other joins are immediate, so
    /// pooling them would add dispatch overhead for zero overlap.
    pool_all: bool,
}

/// Which artifact a lane currently has in flight on the pool; joining
/// it unlocks the next host phase + submit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LaneStep {
    /// embed in flight; join starts layer 0's QKV.
    Embed,
    /// QKV of this layer in flight; join submits selection + drains.
    Qkv(usize),
    /// selection of this layer in flight; join corrects + submits attn.
    Select(usize),
    /// attention of this layer in flight; join appends KV, dispatches
    /// speculative recall, then submits the next QKV (or logits).
    Attn(usize),
    /// logits in flight; join samples one token per sequence.
    Logits,
    /// step complete (or the lane failed and was retired).
    Done,
}

/// One lane being driven by the in-engine lane scheduler.
struct LaneRun<'a, 'b> {
    lane: Lane<'a, 'b>,
    step: LaneStep,
    /// Monotone submission stamp of the in-flight job — the blocking
    /// fallback joins the earliest-submitted lane (FIFO per worker
    /// makes it the likeliest to finish first).
    submitted_at: u64,
    /// First error this lane hit; the lane is retired but the other
    /// lanes complete their step before the error propagates.
    error: Option<anyhow::Error>,
}

/// Which artifact an in-flight chunked prefill currently has pending on
/// the pool.
#[derive(Debug, Clone, Copy)]
enum PrefillPhase {
    /// prompt embedding over the prefill bucket.
    Embed,
    /// `layer_prefill` for this layer.
    Layer(usize),
    /// final logits over the bucketed hidden state.
    Logits,
    /// speculative seeding (single-sequence selection) for this layer.
    Seed(usize),
}

/// One prompt prefill in flight on the executor pool, advanced one
/// artifact ("chunk") at a time from the engine thread. Chunking is
/// what bounds head-of-line blocking: a 100k-token prefill never holds
/// a pool worker for more than one layer's work, so decode lane jobs
/// interleave with it instead of stalling behind the whole prompt.
struct PrefillJob {
    seq: Sequence,
    bucket: usize,
    /// live prompt tokens (<= bucket; the rest is padding).
    len: usize,
    phase: PrefillPhase,
    pending: Option<ExecTicket>,
    /// hidden state entering the next layer chunk.
    h: Option<HostTensor>,
    pos_t: Option<HostTensor>,
    valid_t: Option<HostTensor>,
    /// last-token query per layer (drives speculation seeding).
    q_last: Vec<Vec<f32>>,
    /// the prompt's next-token logits row, extracted at the Logits phase.
    logits_row: Option<Vec<f32>>,
    started: Instant,
}

/// The engine: owns the runtime handle + model config and executes the
/// decode pipeline for batches of sequences.
pub struct Engine {
    /// PJRT runtime handle (artifacts + weights).
    pub rt: Runtime,
    /// Model geometry this engine serves.
    pub cfg: ModelConfig,
    /// Manifest name of `cfg`.
    pub cfg_name: String,
    /// FreeKV algorithm/serving parameters.
    pub params: FreeKvParams,
    /// Cumulative wall-time and counter breakdown.
    pub stats: EngineStats,
    /// disable speculation+correction entirely: run selection blocking
    /// each step (tau=1-like reference mode).
    pub blocking_mode: bool,
    /// when set, per-head query similarities are recorded as
    /// (layer, sims[n_qo]) tuples each decode step (Fig. 3 / Table 8).
    pub record_sims: bool,
    /// Recorded (layer, per-head query similarity) tuples.
    pub sim_trace: Vec<(usize, Vec<f32>)>,
    /// background recall worker (lazily spawned when overlap is active).
    pipeline: Option<RecallPipeline>,
    /// Send-safe executor pool (`params.exec_workers` PJRT clients);
    /// `None` keeps all artifact execution inline on this thread.
    executor: Option<ExecutorPool>,
    /// free-list of selection scratches (one per bucket in rotation).
    sel_scratch: Vec<SelScratch>,
    /// reclaimed batch gather tensors (gk, gv, gvalid).
    attn_scratch: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)>,
    /// chunked prefills in flight on the executor pool.
    prefills: Vec<PrefillJob>,
    /// completed (or failed) async prefills awaiting `prefill_poll`.
    prefill_done: Vec<PrefillDone>,
    /// true while the lane scheduler is driving a decode lane set —
    /// prefill chunks completing in this window are the overlap proof.
    decode_active: bool,
    /// Shared KV page allocator: every sequence's CPU pool pages come
    /// from here (capacity `params.kv_pool_pages`; CoW prefix sharing
    /// and the persistent retained tier per `params.prefix_cache`), and
    /// admission reserves against it.
    alloc: Arc<PageAllocator>,
    /// Deterministic fault-injection plan (`params.chaos_seed`), shared
    /// with the executor pool and the recall worker. `None` in
    /// production: every check site is a single untaken branch.
    faults: Option<Arc<FaultPlan>>,
    /// Latched when the recall worker died (a submit bounced or a job
    /// came back aborted): speculative recall runs serially (exposed)
    /// for the rest of this engine's life instead of wedging on a dead
    /// channel.
    recall_dead: bool,
}

impl Engine {
    /// Build an engine for `cfg_name` from the runtime's manifest:
    /// spawns the executor pool, the shared page allocator, and the
    /// optional fault plan per `params`.
    pub fn new(rt: Runtime, cfg_name: &str, params: FreeKvParams) -> Result<Engine> {
        let cfg = rt.manifest.config(cfg_name)?.clone();
        // Each pool worker owns a full PJRT client built on its own
        // thread (the EngineLoop trick); the engine-thread runtime stays
        // for synchronous prefill and serial dispatch. Weight-bearing
        // jobs are confined to the first `weight_workers` workers so
        // pool weight memory stops scaling with the pool size.
        let executor = if params.exec_workers > 0 {
            Some(ExecutorPool::for_manifest_routed(
                &rt.manifest,
                params.exec_workers,
                params.weight_workers.clamp(1, params.exec_workers),
            )?)
        } else {
            None
        };
        let alloc = PageAllocator::for_model_lock(
            &cfg,
            params.kv_pool_pages as u64,
            params.prefix_cache,
            params.kv_retain_pages as u64,
            params.kv_dtype,
            params.kv_lock,
        );
        let faults = params.chaos_seed.map(|seed| Arc::new(FaultPlan::chaos(seed)));
        if let (Some(pool), Some(plan)) = (&executor, &faults) {
            pool.set_faults(plan.clone());
        }
        Ok(Engine {
            rt,
            cfg,
            cfg_name: cfg_name.to_string(),
            params,
            stats: EngineStats::default(),
            blocking_mode: false,
            record_sims: false,
            sim_trace: Vec::new(),
            pipeline: None,
            executor,
            sel_scratch: Vec::new(),
            attn_scratch: Vec::new(),
            prefills: Vec::new(),
            prefill_done: Vec::new(),
            decode_active: false,
            alloc,
            faults,
            recall_dead: false,
        })
    }

    /// Install a fault plan after construction (tests share one plan
    /// across engine restarts). Must run before the first decode step:
    /// the recall pipeline captures the plan when it is lazily spawned.
    pub fn set_faults(&mut self, plan: Arc<FaultPlan>) {
        if let Some(pool) = &self.executor {
            pool.set_faults(plan.clone());
        }
        self.faults = Some(plan);
    }

    /// Manifest-qualified artifact name: `<cfg_name>_<name>`.
    pub fn art(&self, name: &str) -> String {
        format!("{}_{}", self.cfg_name, name)
    }

    /// Eager-compile every artifact of this engine's config on the
    /// engine-thread runtime AND, when pooled, on every executor worker
    /// (each owns a private executable cache), so the first request pays
    /// no XLA compilation anywhere. Returns the per-runtime artifact
    /// count.
    pub fn warmup(&self) -> Result<usize> {
        let n = self.rt.warmup(&self.cfg_name)?;
        if let Some(pool) = &self.executor {
            pool.warmup(&self.cfg_name)?;
        }
        Ok(n)
    }

    /// Create a fresh sequence for a prompt; its CPU pool pages draw
    /// from the engine's shared allocator.
    pub fn new_sequence(&self, id: u64, prompt: Vec<i32>, max_new: usize, sample: SampleParams) -> Sequence {
        let alloc = self.alloc.clone();
        Sequence::with_alloc(id, &self.cfg, prompt, max_new, Layout::Hnd, sample, alloc)
    }

    /// Live gauges of the shared KV pool.
    pub fn kv_pool_stats(&self) -> KvPoolStats {
        self.alloc.stats()
    }

    fn overlap_active(&self) -> bool {
        self.params.overlap && !self.blocking_mode
    }

    // ------------------------------------------------------------------
    // Prefill
    // ------------------------------------------------------------------

    /// Run prefill for one sequence; returns the next-token logits.
    pub fn prefill(&mut self, seq: &mut Sequence) -> Result<Vec<f32>> {
        let t0 = Instant::now();
        let cfg = self.cfg.clone();
        let len = seq.tokens.len();
        let bucket = self
            .rt
            .manifest
            .prefill_bucket(len)
            .ok_or_else(|| anyhow!("prompt of {} tokens exceeds prefill buckets", len))?;

        let mut toks = seq.tokens.clone();
        toks.resize(bucket, 0);
        let mut pos: Vec<i32> = (0..len as i32).collect();
        pos.resize(bucket, -1);
        let mut valid = vec![1.0f32; len];
        valid.resize(bucket, 0.0);

        let h = self
            .rt
            .run(&self.art(&format!("embed_t{}", bucket)), &[HostTensor::I32(toks, vec![bucket])], None)?
            .remove(0);
        let mut h = h;
        let pos_t = HostTensor::I32(pos, vec![bucket]);
        let valid_t = HostTensor::F32(valid, vec![bucket]);
        let mut q_last_per_layer: Vec<Vec<f32>> = Vec::with_capacity(cfg.n_layers);

        // the prompt is fully known: hash it for prefix-page keys, then
        // adopt the longest cached prefix (resident or retained) so the
        // per-layer offloads below skip pages the cache already holds.
        // GPU prefill still runs for every token — device state stays
        // bit-identical to a cold prefill; only pool writes are saved.
        seq.kv.feed_tokens(&seq.tokens);
        self.stats.prefill_tokens_saved += seq.kv.adopt_prefix() as u64;
        for l in 0..cfg.n_layers {
            let out = self.rt.run(
                &self.art(&format!("layer_prefill_t{}", bucket)),
                &[h.clone(), pos_t.clone(), valid_t.clone()],
                Some(l),
            )?;
            let mut it = out.into_iter();
            h = it.next().unwrap();
            let k = it.next().unwrap().into_f32s()?;
            let v = it.next().unwrap().into_f32s()?;
            let q_last = it.next().unwrap().into_f32s()?;
            // populate GPU cache + offload completed pages (prefix-keyed)
            let completed = seq.kv.layers[l].gpu.load_prefill(&k, &v, len, bucket);
            seq.kv.offload_completed(l, &completed, &mut seq.xfer);
            q_last_per_layer.push(q_last);
        }

        // Final logits of the last valid token.
        let lg = self
            .rt
            .run(
                &self.art(&format!("logits_t{}", bucket)),
                &[h],
                None,
            )?
            .remove(0)
            .into_f32s()?;
        let row = &lg[(len - 1) * cfg.vocab..len * cfg.vocab];

        // Seed speculation: select with the last prompt token's query.
        for l in 0..cfg.n_layers {
            let q = &q_last_per_layer[l];
            let sel = self.run_selection_single(seq, l, q)?;
            for (m, pages) in sel.iter().enumerate() {
                let n = seq.kv.apply_selection(l, m, pages, &mut seq.xfer);
                self.stats.recalled_pages += n as u64;
            }
            seq.spec[l].store(q);
        }

        self.stats.prefills += 1;
        self.stats.prefill_secs += t0.elapsed().as_secs_f64();
        Ok(row.to_vec())
    }

    // ------------------------------------------------------------------
    // Decode
    // ------------------------------------------------------------------

    /// Run one decode step for a batch of sequences (all must have at
    /// least one token; finished lanes are skipped by the caller).
    /// Appends the sampled token to each sequence.
    ///
    /// The step is a sequence of lane phases. Under pooled dispatch the
    /// phase split is what buys overlap: selection scoring executes on
    /// an executor worker while this thread drains the recall pipeline,
    /// and joins just before the correction check needs the result.
    /// Serial dispatch executes each phase inline in the same order.
    pub fn decode_step(&mut self, seqs: &mut [&mut Sequence]) -> Result<()> {
        let t_step = Instant::now();
        self.ensure_pipeline();
        let n_layers = self.cfg.n_layers;
        {
            let mut lane = self.lane_start(&mut *seqs, false)?;
            self.lane_embed_join(&mut lane)?;
            for l in 0..n_layers {
                self.lane_qkv_submit(&mut lane, l)?;
                self.lane_qkv_join(&mut lane)?;
                self.lane_select_submit(&mut lane, l)?;
                self.lane_drain(&mut lane, l);
                // Selection is scoring on a pool worker: spend the
                // sliver advancing any completed prefill chunks, so a
                // prefill progresses once per *layer* during joint
                // decode instead of once per scheduler tick (chunk-
                // paced TTFT would otherwise scale with n_layers).
                // These folds happen under in-flight decode, so they
                // count toward the overlap proof.
                if !self.prefills.is_empty() {
                    self.decode_active = true;
                    self.prefill_advance();
                    self.decode_active = false;
                }
                self.lane_select_join(&mut lane)?;
                self.lane_correct(&mut lane, l);
                self.lane_attn_submit(&mut lane, l)?;
                self.lane_attn_join(&mut lane, l)?;
            }
            self.lane_logits_submit(&mut lane)?;
            self.lane_logits_join(&mut lane)?;
        }

        // Chunks that completed during the step's tail still overlapped
        // in-flight decode; fold them with the overlap credit.
        if !self.prefills.is_empty() {
            self.decode_active = true;
            self.prefill_advance();
            self.decode_active = false;
        }

        // Finished sequences leave the batch after this step: reclaim
        // their in-flight transfer halves so nothing strands on the
        // worker.
        for seq in seqs.iter_mut() {
            if seq.done() {
                self.drain_sequence(seq);
            }
        }

        self.stats.steps += 1;
        self.stats.decode_secs += t_step.elapsed().as_secs_f64();
        self.sync_pool_stats();
        Ok(())
    }

    /// Decode N disjoint microbatch lanes through the in-engine lane
    /// scheduler. The caller's partition is advisory: the batch is
    /// flattened and re-planned bucket-aware ([`Engine::plan_lanes`]),
    /// which also recovers the pair-merge rule — lanes that would pad
    /// to the joint batch's compiled bucket are merged back into one
    /// joint step, since splitting there only duplicates artifact
    /// compute. Outputs are bit-identical to decoding each lane
    /// serially: per-sequence computation is independent of lane
    /// composition (padding lanes are masked), so lane scheduling is a
    /// pure wall-clock change.
    pub fn decode_step_lanes(&mut self, lanes: &mut [Vec<&mut Sequence>]) -> Result<()> {
        let flat: Vec<&mut Sequence> = lanes
            .iter_mut()
            .flat_map(|l| l.iter_mut().map(|s| &mut **s))
            .collect();
        if flat.is_empty() {
            return Ok(());
        }
        self.decode_batch(flat)
    }

    /// Decode a joint batch of any width: planned into bucket-aware
    /// lanes, pipelined through the executor pool when one exists, run
    /// back to back otherwise.
    fn decode_batch(&mut self, mut flat: Vec<&mut Sequence>) -> Result<()> {
        let widths = self.plan_lanes(flat.len());
        if widths.len() == 1 {
            return self.decode_step(&mut flat);
        }
        let mut parts: Vec<Vec<&mut Sequence>> = Vec::with_capacity(widths.len());
        let mut it = flat.into_iter();
        for w in &widths {
            parts.push(it.by_ref().take(*w).collect());
        }
        if self.executor.is_none() {
            // Serial dispatch: lanes run back to back with the same
            // per-lane error containment as the default trait impl.
            return contain_lanes(parts.iter_mut(), |part| self.decode_step(part));
        }
        let t_step = Instant::now();
        self.ensure_pipeline();
        let max_inflight = self.params.max_lanes.max(1);
        self.decode_active = true;
        let result = self.run_lane_set(&mut parts, max_inflight);
        // Chunks that finished on workers during the lane set but were
        // not folded in an idle sliver still count as overlapped work.
        self.prefill_advance();
        self.decode_active = false;
        // Finished sequences leave the batch after this step: reclaim
        // their in-flight transfer halves.
        for seq in parts.iter_mut().flat_map(|p| p.iter_mut()) {
            if seq.done() {
                self.drain_sequence(seq);
            }
        }
        // One microbatch decode invocation per lane, one wall interval.
        self.stats.steps += parts.len() as u64;
        self.stats.decode_secs += t_step.elapsed().as_secs_f64();
        self.sync_pool_stats();
        result
    }

    /// Bucket-aware lane plan for a joint batch of `total` sequences:
    /// balanced lane widths. Chooses the lane count (between the
    /// minimum that fits the largest compiled bucket and
    /// `params.max_lanes`) minimizing total padded artifact compute
    /// `N * bucket(ceil(total/N))`; ties go to the fewest lanes —
    /// splitting without shrinking the per-lane bucket only duplicates
    /// compute, which is the old pair-merge rule generalized.
    fn plan_lanes(&self, total: usize) -> Vec<usize> {
        let cap = self.rt.manifest.decode_batch_buckets.iter().copied().max().unwrap_or(0);
        if cap == 0 {
            // no compiled decode buckets: let decode_step surface it
            return vec![total];
        }
        let n_min = total.div_ceil(cap).max(1);
        let n_max = self.params.max_lanes.max(n_min).min(total);
        let mut best: Option<(usize, usize)> = None; // (cost, n)
        for n in n_min..=n_max {
            let w = total.div_ceil(n);
            let Some(b) = self.rt.manifest.decode_bucket(w) else { continue };
            let cost = n * b;
            let better = match best {
                Some((c, _)) => cost < c,
                None => true,
            };
            if better {
                best = Some((cost, n));
            }
        }
        let Some((_, n)) = best else {
            return vec![total]; // even the narrowest lane has no bucket
        };
        crate::util::balanced_widths(total, n)
    }

    /// The lane scheduler: drive every lane's submit/join state machine,
    /// advancing whichever lane's pool ticket completes next (no fixed
    /// alternation), with at most `max_inflight` lanes in flight —
    /// further lanes start as earlier ones finish. While no decode
    /// ticket is ready, completed prefill chunks are advanced instead
    /// (that idle sliver is exactly where prefill overlap comes from);
    /// only when nothing at all is ready does the scheduler block, on
    /// the earliest-submitted lane. A lane that fails is retired and
    /// the others complete their step before the first error returns.
    fn run_lane_set<'a, 'b>(
        &mut self,
        parts: &'a mut [Vec<&'b mut Sequence>],
        max_inflight: usize,
    ) -> Result<()> {
        let n_layers = self.cfg.n_layers;
        let concurrent = parts.len().min(max_inflight);
        if concurrent > 1 {
            self.stats.lane_sets += 1;
        }
        self.stats.max_lanes_inflight = self.stats.max_lanes_inflight.max(concurrent as u64);
        let mut submit_seq: u64 = 0;
        // Error of a lane that could not even start (no LaneRun exists
        // for it); reported alongside per-lane failures.
        let mut start_err: Option<anyhow::Error> = None;
        let mut parts_iter = parts.iter_mut();
        let mut runs: Vec<LaneRun<'a, 'b>> = Vec::with_capacity(concurrent);
        while runs.len() < max_inflight {
            let Some(part) = parts_iter.next() else { break };
            let lane = self.lane_start(part.as_mut_slice(), true)?;
            runs.push(LaneRun { lane, step: LaneStep::Embed, submitted_at: submit_seq, error: None });
            submit_seq += 1;
        }
        loop {
            let mut any_live = false;
            let mut progressed = false;
            for i in 0..runs.len() {
                if runs[i].step == LaneStep::Done {
                    continue;
                }
                any_live = true;
                match Self::poll_lane(&mut runs[i]) {
                    Ok(false) => {}
                    Ok(true) => {
                        progressed = true;
                        self.advance_lane(&mut runs[i], n_layers, &mut submit_seq);
                    }
                    Err(e) => {
                        progressed = true;
                        Self::fail_lane(&mut runs[i], e);
                    }
                }
                if runs[i].step == LaneStep::Done {
                    // a lane finished: admit the next queued lane
                    if let Some(part) = parts_iter.next() {
                        match self.lane_start(part.as_mut_slice(), true) {
                            Ok(lane) => {
                                runs.push(LaneRun {
                                    lane,
                                    step: LaneStep::Embed,
                                    submitted_at: submit_seq,
                                    error: None,
                                });
                                submit_seq += 1;
                            }
                            Err(e) => {
                                // lane never started: its sequences skip
                                // this step; the error surfaces at the end
                                if start_err.is_none() {
                                    start_err = Some(e);
                                }
                            }
                        }
                    }
                }
            }
            if !any_live {
                break;
            }
            if progressed {
                continue;
            }
            // No decode ticket ready: give completed prefill chunks the
            // idle sliver, then re-poll the lanes.
            if self.prefill_advance() > 0 {
                continue;
            }
            // Everything is genuinely executing: block on the lane
            // whose job was submitted earliest.
            let Some(i) = runs
                .iter()
                .enumerate()
                .filter(|(_, r)| r.step != LaneStep::Done)
                .min_by_key(|(_, r)| r.submitted_at)
                .map(|(i, _)| i)
            else {
                break;
            };
            match Self::block_lane(&mut runs[i]) {
                Ok(()) => self.advance_lane(&mut runs[i], n_layers, &mut submit_seq),
                Err(e) => Self::fail_lane(&mut runs[i], e),
            }
        }
        // Every lane ran to completion or was retired; surface the
        // first failure only now, with the other lanes' tokens safely
        // appended.
        for run in runs.iter_mut() {
            if let Some(e) = run.error.take() {
                return Err(e);
            }
        }
        match start_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Non-blocking: fold the lane's pool ticket into a ready result if
    /// it has completed. `Ok(true)` means the lane can advance now.
    fn poll_lane(run: &mut LaneRun<'_, '_>) -> Result<bool> {
        let polled = match run.lane.pending.as_ref() {
            Some(Pending::Ticket(t)) => t.try_wait(),
            // inline result already buffered, or nothing pending (the
            // next advance will surface the phase mismatch)
            _ => return Ok(true),
        };
        match polled {
            None => Ok(false),
            Some(Ok(done)) => {
                run.lane.pending = Some(Pending::Ready {
                    outputs: done.outputs,
                    inputs: done.inputs,
                    busy_secs: done.busy_secs,
                    waited_secs: 0.0,
                });
                Ok(true)
            }
            Some(Err(e)) => Err(e),
        }
    }

    /// Blocking: wait for the lane's pool ticket, folding the result
    /// (and the time this thread actually blocked) for the next advance.
    fn block_lane(run: &mut LaneRun<'_, '_>) -> Result<()> {
        if matches!(run.lane.pending.as_ref(), Some(Pending::Ticket(_))) {
            let Some(Pending::Ticket(t)) = run.lane.pending.take() else { unreachable!() };
            let t0 = Instant::now();
            let done = t.wait()?;
            run.lane.pending = Some(Pending::Ready {
                outputs: done.outputs,
                inputs: done.inputs,
                busy_secs: done.busy_secs,
                waited_secs: t0.elapsed().as_secs_f64(),
            });
        }
        Ok(())
    }

    /// Retire a failed lane; its sequences do not advance this step.
    fn fail_lane(run: &mut LaneRun<'_, '_>, e: anyhow::Error) {
        if run.error.is_none() {
            run.error = Some(e);
        }
        run.step = LaneStep::Done;
        run.lane.pending = None;
    }

    /// One state-machine transition: join the completed artifact, do
    /// the host-side phase work, submit the lane's next artifact. Phase
    /// errors retire the lane (`fail_lane`) without touching the others.
    fn advance_lane(&mut self, run: &mut LaneRun<'_, '_>, n_layers: usize, submit_seq: &mut u64) {
        let step = run.step;
        let advanced = (|| -> Result<LaneStep> {
            match step {
                LaneStep::Embed => {
                    self.lane_embed_join(&mut run.lane)?;
                    self.lane_qkv_submit(&mut run.lane, 0)?;
                    Ok(LaneStep::Qkv(0))
                }
                LaneStep::Qkv(l) => {
                    self.lane_qkv_join(&mut run.lane)?;
                    self.lane_select_submit(&mut run.lane, l)?;
                    // the drain waits on the recall worker while the
                    // just-submitted selection scores on a pool worker
                    self.lane_drain(&mut run.lane, l);
                    Ok(LaneStep::Select(l))
                }
                LaneStep::Select(l) => {
                    self.lane_select_join(&mut run.lane)?;
                    self.lane_correct(&mut run.lane, l);
                    self.lane_attn_submit(&mut run.lane, l)?;
                    Ok(LaneStep::Attn(l))
                }
                LaneStep::Attn(l) => {
                    self.lane_attn_join(&mut run.lane, l)?;
                    if l + 1 < n_layers {
                        self.lane_qkv_submit(&mut run.lane, l + 1)?;
                        Ok(LaneStep::Qkv(l + 1))
                    } else {
                        self.lane_logits_submit(&mut run.lane)?;
                        Ok(LaneStep::Logits)
                    }
                }
                LaneStep::Logits => {
                    self.lane_logits_join(&mut run.lane)?;
                    Ok(LaneStep::Done)
                }
                LaneStep::Done => Ok(LaneStep::Done),
            }
        })();
        match advanced {
            Ok(next) => {
                run.step = next;
                if next != LaneStep::Done {
                    *submit_seq += 1;
                    run.submitted_at = *submit_seq;
                }
            }
            Err(e) => Self::fail_lane(run, e),
        }
    }

    // ------------------------------------------------------------------
    // Lane phases (shared by decode_step and the lane scheduler)
    // ------------------------------------------------------------------

    fn ensure_pipeline(&mut self) {
        if self.overlap_active() && self.pipeline.is_none() {
            self.pipeline = Some(RecallPipeline::with_faults(
                self.cfg.page_size,
                self.cfg.d_head,
                self.faults.clone(),
            ));
        }
    }

    /// Dispatch an artifact execution: to the pool when `pooled` (and a
    /// pool exists), inline otherwise. Inline execution happens *here*
    /// (submit time), so serial dispatch preserves the exact historical
    /// op order.
    fn dispatch_in(&mut self, job: ExecJob, pooled: bool) -> Result<Pending> {
        if pooled {
            if let Some(pool) = &self.executor {
                if pool.ready_for(&job) {
                    self.stats.exec_jobs += 1;
                    return Ok(Pending::Ticket(pool.submit(job)));
                }
                // Degradation ladder: no live (or revivable) worker can
                // take this job — execute inline on the engine thread
                // rather than fail the request.
                self.stats.exec_inline_fallbacks += 1;
            }
        }
        let (name, layer, args) = job.into_parts();
        let t0 = Instant::now();
        let outputs = self.rt.run(&name, &args, layer)?;
        let busy = t0.elapsed().as_secs_f64();
        Ok(Pending::Ready { outputs, inputs: args, busy_secs: busy, waited_secs: busy })
    }

    /// Join a pending execution: (outputs, returned inputs, worker busy
    /// seconds, seconds this thread actually blocked). For inline
    /// executions the two times coincide; for a completion the lane
    /// scheduler already observed, the blocked time is ~0.
    fn join(p: Pending) -> Result<(Vec<HostTensor>, Vec<HostTensor>, f64, f64)> {
        match p {
            Pending::Ready { outputs, inputs, busy_secs, waited_secs } => {
                Ok((outputs, inputs, busy_secs, waited_secs))
            }
            Pending::Ticket(t) => {
                let t0 = Instant::now();
                let done = t.wait()?;
                Ok((done.outputs, done.inputs, done.busy_secs, t0.elapsed().as_secs_f64()))
            }
        }
    }

    /// Open a lane over one microbatch: bucket lookup, batching stats,
    /// and the embed dispatch.
    fn lane_start<'a, 'b>(
        &mut self,
        seqs: &'a mut [&'b mut Sequence],
        pool_all: bool,
    ) -> Result<Lane<'a, 'b>> {
        let n = seqs.len();
        self.stats.max_batch_lanes = self.stats.max_batch_lanes.max(n as u64);
        if n > 1 {
            self.stats.batched_steps += 1;
        }
        let bucket = self
            .rt
            .manifest
            .decode_bucket(n)
            .ok_or_else(|| anyhow!("batch {} exceeds decode buckets", n))?;
        let mut toks: Vec<i32> = seqs.iter().map(|q| *q.tokens.last().unwrap()).collect();
        toks.resize(bucket, 0);
        let mut pos: Vec<i32> = seqs.iter().map(|q| q.pos() as i32).collect();
        pos.resize(bucket, 0);
        let name = self.art(&format!("embed_b{}", bucket));
        let pending = self.dispatch_in(
            ExecJob::Embed { name, args: vec![HostTensor::I32(toks, vec![bucket])] },
            pool_all,
        )?;
        Ok(Lane {
            seqs,
            n,
            bucket,
            h: None,
            pos_t: Some(HostTensor::I32(pos, vec![bucket])),
            pending: Some(pending),
            q_all: Vec::new(),
            k_new: Vec::new(),
            v_new: Vec::new(),
            qkv_t: None,
            sel_pages: Vec::new(),
            pool_all,
        })
    }

    fn lane_embed_join(&mut self, lane: &mut Lane<'_, '_>) -> Result<()> {
        let pending = lane.pending.take().expect("embed in flight");
        let (mut outputs, _inputs, _busy, _waited) = Self::join(pending)?;
        lane.h = Some(outputs.remove(0));
        Ok(())
    }

    /// QKV (split from attention so correction can intercept between
    /// computing q_i and attending, per Fig. 4b).
    fn lane_qkv_submit(&mut self, lane: &mut Lane<'_, '_>, l: usize) -> Result<()> {
        let name = self.art(&format!("layer_qkv_b{}", lane.bucket));
        let args = vec![
            lane.h.take().expect("hidden state present"),
            lane.pos_t.take().expect("pos tensor present"),
        ];
        let pooled = lane.pool_all;
        lane.pending = Some(self.dispatch_in(ExecJob::Qkv { name, layer: l, args }, pooled)?);
        Ok(())
    }

    fn lane_qkv_join(&mut self, lane: &mut Lane<'_, '_>) -> Result<()> {
        let pending = lane.pending.take().expect("qkv in flight");
        let (outputs, mut inputs, _busy, waited) = Self::join(pending)?;
        self.stats.qkv_secs += waited;
        let mut it = outputs.into_iter();
        let q_t = it.next().unwrap();
        let k_new_t = it.next().unwrap();
        let v_new_t = it.next().unwrap();
        lane.q_all = q_t.f32s()?.to_vec();
        lane.k_new = k_new_t.f32s()?.to_vec();
        lane.v_new = v_new_t.f32s()?.to_vec();
        lane.qkv_t = Some((q_t, k_new_t, v_new_t));
        // recover the layer input h and the reusable pos tensor
        lane.pos_t = Some(inputs.pop().expect("pos tensor returned"));
        lane.h = Some(inputs.pop().expect("hidden state returned"));
        Ok(())
    }

    /// Selection with the current step's queries (batched): used at this
    /// layer for corrected heads, and for the NEXT step's speculative
    /// reuse. Needs only the compute half of the KV state, so under
    /// pooled dispatch it scores on a worker while the engine drains the
    /// recall pipeline — selection scoring leaves the critical path.
    fn lane_select_submit(&mut self, lane: &mut Lane<'_, '_>, l: usize) -> Result<()> {
        let (m, dh, p) = (self.cfg.n_kv, self.cfg.d_head, self.cfg.n_pages_max());
        let bucket = lane.bucket;
        // Host-side input build counts as selection time (it did in the
        // monolithic run_selection_batch; keeps the real-breakdown
        // exhibit comparable across PRs).
        let t_fill = Instant::now();
        let mut scratch = self.take_sel_scratch(bucket);
        {
            let mut it = scratch.args.iter_mut();
            let (qt, smin_t, smax_t, mask_t) =
                (it.next().unwrap(), it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
            let (
                HostTensor::F32(qd, _),
                HostTensor::F32(lo, _),
                HostTensor::F32(hi, _),
                HostTensor::F32(mk, _),
            ) = (qt, smin_t, smax_t, mask_t)
            else {
                unreachable!("selection scratch is always f32")
            };
            qd[..lane.q_all.len()].copy_from_slice(&lane.q_all);
            qd[lane.q_all.len()..].iter_mut().for_each(|x| *x = 0.0);
            for (i, seq) in lane.seqs.iter().enumerate() {
                let gpu = &seq.kv.layers[l].gpu;
                gpu.summaries_sanitized_into(
                    &mut lo[i * m * p * dh..(i + 1) * m * p * dh],
                    &mut hi[i * m * p * dh..(i + 1) * m * p * dh],
                );
                gpu.selectable_mask_into(&mut mk[i * p..(i + 1) * p]);
            }
            // padded lanes: clean mask so the artifact selects nothing
            for pad in lane.n..bucket {
                mk[pad * p..(pad + 1) * p].iter_mut().for_each(|x| *x = 0.0);
            }
        }
        let name = self.art(&format!("select_{}_b{}", self.params.variant.as_str(), bucket));
        self.stats.select_secs += t_fill.elapsed().as_secs_f64();
        lane.pending = Some(self.dispatch_in(ExecJob::Selection { name, args: scratch.args }, true)?);
        Ok(())
    }

    /// Drain: re-attach this layer's transfer half (the previous step's
    /// speculative recall) before anything below touches the select
    /// table or pool. Under pooled dispatch this wait runs concurrently
    /// with the in-flight selection scoring.
    fn lane_drain(&mut self, lane: &mut Lane<'_, '_>, l: usize) {
        for seq in lane.seqs.iter_mut() {
            self.drain_layer(seq, l);
        }
    }

    fn lane_select_join(&mut self, lane: &mut Lane<'_, '_>) -> Result<()> {
        let pending = lane.pending.take().expect("selection in flight");
        let (outputs, inputs, busy, waited) = Self::join(pending)?;
        self.stats.select_secs += waited;
        self.stats.select_hidden_secs += (busy - waited).max(0.0);
        // Index filtering is selection time too (see lane_select_submit).
        let t_filter = Instant::now();
        let idx = outputs[1].i32s()?;
        let HostTensor::F32(mk, _) = &inputs[3] else {
            unreachable!("selection scratch is always f32")
        };
        let (m, p) = (self.cfg.n_kv, self.cfg.n_pages_max());
        let k_sel = self.cfg.select_pages;
        let mut result = Vec::with_capacity(lane.n);
        for i in 0..lane.n {
            let mut per_head = Vec::with_capacity(m);
            for head in 0..m {
                let base = (i * m + head) * k_sel;
                let pages: Vec<usize> = idx[base..base + k_sel]
                    .iter()
                    .map(|&x| x as usize)
                    .filter(|&pg| pg < p && mk[i * p + pg] > 0.0)
                    .collect();
                per_head.push(pages);
            }
            result.push(per_head);
        }
        lane.sel_pages = result;
        self.sel_scratch.push(SelScratch { bucket: lane.bucket, args: inputs });
        self.stats.select_secs += t_filter.elapsed().as_secs_f64();
        Ok(())
    }

    /// Correction check + blocking recall for flagged heads.
    fn lane_correct(&mut self, lane: &mut Lane<'_, '_>, l: usize) {
        let (m, dh, qo) = (self.cfg.n_kv, self.cfg.d_head, self.cfg.n_qo);
        for (i, seq) in lane.seqs.iter_mut().enumerate() {
            let q_i = &lane.q_all[i * qo * dh..(i + 1) * qo * dh];
            // Following the paper (App. A), compression heuristics are
            // not applied to the first layer: its query similarity is
            // inherently low (h = embedding only), so layer 0 always
            // runs blocking selection and is excluded from correction
            // statistics.
            let decision = if self.blocking_mode || l == 0 {
                None
            } else {
                seq.spec[l].head_similarities(q_i).map(|sims| {
                    self.stats.correction_checks += m as u64;
                    if self.record_sims {
                        self.sim_trace.push((l, sims.clone()));
                    }
                    correction_check(&sims, m, &self.params)
                })
            };
            match decision {
                Some(d) => {
                    for &head in &d.corrected_heads {
                        self.stats.corrections += 1;
                        let t1 = Instant::now();
                        let nrec =
                            seq.kv.apply_selection(l, head, &lane.sel_pages[i][head], &mut seq.xfer);
                        let dt = t1.elapsed().as_secs_f64();
                        self.stats.recall_secs += dt;
                        self.stats.recall_exposed_secs += dt;
                        self.stats.recalled_pages += nrec as u64;
                    }
                    let hit = m - d.corrected_heads.len();
                    self.stats.speculative_hits += hit as u64;
                }
                None => {
                    // blocking/first-layer path: install the current
                    // selection before attention.
                    for head in 0..m {
                        let t1 = Instant::now();
                        let nrec =
                            seq.kv.apply_selection(l, head, &lane.sel_pages[i][head], &mut seq.xfer);
                        let dt = t1.elapsed().as_secs_f64();
                        self.stats.recall_secs += dt;
                        self.stats.recall_exposed_secs += dt;
                        self.stats.recalled_pages += nrec as u64;
                    }
                }
            }
        }
    }

    /// Incremental gather into persistent per-seq lanes, then dispatch
    /// attention.
    fn lane_attn_submit(&mut self, lane: &mut Lane<'_, '_>, l: usize) -> Result<()> {
        let (m, dh, s) = (self.cfg.n_kv, self.cfg.d_head, self.cfg.budget_slots());
        let bucket = lane.bucket;
        let t0 = Instant::now();
        let (mut gk, mut gv, mut gvalid) = self.take_attn_scratch(bucket, m, s, dh);
        for (i, seq) in lane.seqs.iter_mut().enumerate() {
            let (gpu, x) = seq.kv.layers[l].parts_mut();
            let buf = &mut seq.gather[l];
            gpu.gather_dirty(&mut x.select, &mut buf.k, &mut buf.v, &mut buf.valid);
            gk[i * m * s * dh..(i + 1) * m * s * dh].copy_from_slice(&buf.k);
            gv[i * m * s * dh..(i + 1) * m * s * dh].copy_from_slice(&buf.v);
            gvalid[i * m * s..(i + 1) * m * s].copy_from_slice(&buf.valid);
        }
        for pad in lane.n..bucket {
            gvalid[pad * m * s..(pad + 1) * m * s].iter_mut().for_each(|v| *v = 0.0);
        }
        self.stats.gather_secs += t0.elapsed().as_secs_f64();

        let (q_t, k_new_t, v_new_t) = lane.qkv_t.take().expect("qkv tensors present");
        let args = vec![
            lane.h.take().expect("hidden state present"),
            q_t,
            k_new_t,
            v_new_t,
            HostTensor::F32(gk, vec![bucket, m, s, dh]),
            HostTensor::F32(gv, vec![bucket, m, s, dh]),
            HostTensor::F32(gvalid, vec![bucket, m, s]),
        ];
        let name = self.art(&format!("layer_attn_b{}", bucket));
        let pooled = lane.pool_all;
        lane.pending = Some(self.dispatch_in(ExecJob::Attention { name, layer: l, args }, pooled)?);
        Ok(())
    }

    /// Join attention, then the host-side tail of the layer: KV append +
    /// offload, speculative recall dispatch for the next step, and the
    /// query snapshot for the next correction check.
    fn lane_attn_join(&mut self, lane: &mut Lane<'_, '_>, l: usize) -> Result<()> {
        let pending = lane.pending.take().expect("attention in flight");
        let (outputs, inputs, _busy, waited) = Self::join(pending)?;
        self.stats.attn_secs += waited;
        lane.h = Some(outputs.into_iter().next().expect("attention output"));
        // reclaim the big gather tensors for the next layer/step
        let mut it = inputs.into_iter().skip(4);
        if let (
            Some(HostTensor::F32(a, _)),
            Some(HostTensor::F32(b, _)),
            Some(HostTensor::F32(c, _)),
        ) = (it.next(), it.next(), it.next())
        {
            self.attn_scratch.push((a, b, c));
        }

        let (m, dh, qo) = (self.cfg.n_kv, self.cfg.d_head, self.cfg.n_qo);
        // ---- append new KV, offload completed pages (prefix-keyed:
        // the token this K/V belongs to is already in seq.tokens) ----
        for (i, seq) in lane.seqs.iter_mut().enumerate() {
            seq.kv.feed_tokens(&seq.tokens);
            let kn = &lane.k_new[i * m * dh..(i + 1) * m * dh];
            let vn = &lane.v_new[i * m * dh..(i + 1) * m * dh];
            seq.kv.append(l, kn, vn, &mut seq.xfer);
        }

        // ---- speculative recall for the NEXT step (non-corrected
        // heads; page-cache diff makes re-selection cheap). With
        // overlap on, the transfer half is checked out to the worker
        // and the recall hides under the remaining layers' compute;
        // serial mode keeps it inline as the ablation baseline. ----
        if !self.blocking_mode {
            for (i, seq) in lane.seqs.iter_mut().enumerate() {
                let mut serial = !self.overlap_active() || self.recall_dead;
                if !serial {
                    let xfer = seq.kv.layers[l].take_xfer();
                    let submitted = self.pipeline.as_mut().expect("pipeline active").submit(
                        RecallJob {
                            seq_uid: seq.uid,
                            layer: l,
                            selections: lane.sel_pages[i].clone(),
                            xfer,
                        },
                    );
                    match submitted {
                        Ok(()) => {
                            self.stats.recall_jobs += 1;
                            // sweep finished completions first so this
                            // counts actual worker backlog, not
                            // jobs-since-drain
                            let pipe = self.pipeline.as_mut().expect("pipeline active");
                            pipe.poll();
                            let depth = pipe.pending() as u64;
                            self.stats.max_queue_depth = self.stats.max_queue_depth.max(depth);
                        }
                        Err(job) => {
                            // Degradation ladder: the recall worker's
                            // channel is gone. Re-attach the transfer
                            // half and run this (and every future)
                            // recall serially instead of wedging.
                            seq.kv.layers[l].put_xfer(job.xfer);
                            self.recall_dead = true;
                            self.stats.recall_fallbacks += 1;
                            serial = true;
                        }
                    }
                }
                if serial {
                    for head in 0..m {
                        let t1 = Instant::now();
                        let nrec =
                            seq.kv.apply_selection(l, head, &lane.sel_pages[i][head], &mut seq.xfer);
                        let dt = t1.elapsed().as_secs_f64();
                        self.stats.recall_secs += dt;
                        self.stats.recall_exposed_secs += dt;
                        self.stats.recalled_pages += nrec as u64;
                    }
                }
            }
        }

        // remember q for the next step's correction check
        for (i, seq) in lane.seqs.iter_mut().enumerate() {
            seq.spec[l].store(&lane.q_all[i * qo * dh..(i + 1) * qo * dh]);
        }
        Ok(())
    }

    fn lane_logits_submit(&mut self, lane: &mut Lane<'_, '_>) -> Result<()> {
        let name = self.art(&format!("logits_b{}", lane.bucket));
        let args = vec![lane.h.take().expect("hidden state present")];
        let pooled = lane.pool_all;
        lane.pending = Some(self.dispatch_in(ExecJob::Logits { name, args }, pooled)?);
        Ok(())
    }

    /// Join logits and sample one token per sequence.
    fn lane_logits_join(&mut self, lane: &mut Lane<'_, '_>) -> Result<()> {
        let pending = lane.pending.take().expect("logits in flight");
        let (outputs, _inputs, _busy, waited) = Self::join(pending)?;
        self.stats.logits_secs += waited;
        let lg = outputs.into_iter().next().expect("logits output").into_f32s()?;
        let vocab = self.cfg.vocab;
        for (i, seq) in lane.seqs.iter_mut().enumerate() {
            let row = &lg[i * vocab..(i + 1) * vocab];
            let tok = sample_token(row, &seq.sample, &mut seq.rng);
            seq.tokens.push(tok);
            if Some(tok) == seq.eos {
                seq.finished = true;
            }
        }
        Ok(())
    }

    /// Re-attach one layer's transfer half if its speculative-recall job
    /// is still in flight; merges the worker's counters/stats.
    fn drain_layer(&mut self, seq: &mut Sequence, layer: usize) {
        if !seq.kv.layers[layer].in_flight() {
            return;
        }
        let t0 = Instant::now();
        let done = self
            .pipeline
            .as_mut()
            .expect("transfer half checked out but no pipeline is running")
            .wait(seq.uid, layer)
            .expect("recall worker hung up with a transfer half checked out");
        let waited = t0.elapsed().as_secs_f64();
        // Of the worker's busy time, the part we just blocked for was NOT
        // hidden; only the remainder ran under compute.
        self.stats.recall_exposed_secs += waited;
        self.stats.recall_hidden_secs += (done.busy_secs - waited).max(0.0);
        self.stats.recall_secs += done.busy_secs;
        self.stats.recalled_pages += done.recalled_pages as u64;
        seq.xfer.counters = seq.xfer.counters.merged(&done.counters);
        seq.kv.layers[layer].put_xfer(done.xfer);
        if let Some(selections) = done.aborted {
            // Degradation ladder: the worker died (or panicked) holding
            // this job and bounced it back. Redo the echoed selection
            // inline — `apply_selection` diffs against the slots the
            // worker may have partially installed, so the redo
            // converges — and stay serial from here on.
            self.recall_dead = true;
            self.stats.recall_fallbacks += 1;
            for (head, sel) in selections.iter().enumerate() {
                let t1 = Instant::now();
                let nrec = seq.kv.apply_selection(layer, head, sel, &mut seq.xfer);
                let dt = t1.elapsed().as_secs_f64();
                self.stats.recall_secs += dt;
                self.stats.recall_exposed_secs += dt;
                self.stats.recalled_pages += nrec as u64;
            }
        }
    }

    /// Block until every in-flight recall job of this sequence has been
    /// re-attached. Called automatically when a sequence finishes inside
    /// `decode_step`; callers abandoning a sequence mid-generation must
    /// call it themselves before dropping the engine.
    pub fn drain_sequence(&mut self, seq: &mut Sequence) {
        if self.pipeline.is_none() {
            return;
        }
        for l in 0..self.cfg.n_layers {
            self.drain_layer(seq, l);
        }
    }

    // ------------------------------------------------------------------
    // Chunked prefill on the executor pool
    // ------------------------------------------------------------------

    /// Begin a prefill. With an executor pool the prompt is processed as
    /// chunked pool jobs (embed, per-layer prefill, logits, per-layer
    /// speculation seeding) advanced from the engine thread between
    /// decode work — a long prefill overlaps in-flight decode lanes
    /// instead of stalling them. Without a pool this is the synchronous
    /// path, completed before returning. Chunked and synchronous
    /// prefill run the same artifacts on the same inputs in the same
    /// order, so results are bit-identical.
    pub fn prefill_begin(&mut self, mut seq: Sequence) -> Option<PrefillDone> {
        let pool_ready = match &self.executor {
            Some(pool) => pool.ready_weight(),
            None => false,
        };
        if !pool_ready {
            // No pool, or no live weight-bearing worker left (respawn
            // budget exhausted): degrade to the synchronous inline
            // prefill rather than queue chunks to a dead pool.
            if self.executor.is_some() {
                self.stats.exec_inline_fallbacks += 1;
            }
            let result = self.prefill(&mut seq);
            return Some(PrefillDone { seq, result });
        }
        let len = seq.tokens.len();
        let Some(bucket) = self.rt.manifest.prefill_bucket(len) else {
            let result = Err(anyhow!("prompt of {} tokens exceeds prefill buckets", len));
            return Some(PrefillDone { seq, result });
        };
        let mut toks = seq.tokens.clone();
        toks.resize(bucket, 0);
        let mut pos: Vec<i32> = (0..len as i32).collect();
        pos.resize(bucket, -1);
        let mut valid = vec![1.0f32; len];
        valid.resize(bucket, 0.0);
        let name = self.art(&format!("embed_t{}", bucket));
        let ticket =
            self.pool_submit(ExecJob::Embed { name, args: vec![HostTensor::I32(toks, vec![bucket])] });
        let n_layers = self.cfg.n_layers;
        self.prefills.push(PrefillJob {
            seq,
            bucket,
            len,
            phase: PrefillPhase::Embed,
            pending: Some(ticket),
            h: None,
            pos_t: Some(HostTensor::I32(pos, vec![bucket])),
            valid_t: Some(HostTensor::F32(valid, vec![bucket])),
            q_last: Vec::with_capacity(n_layers),
            logits_row: None,
            started: Instant::now(),
        });
        None
    }

    /// Non-blocking: advance chunked prefills and hand back completions.
    pub fn prefill_poll(&mut self) -> Vec<PrefillDone> {
        self.prefill_advance();
        std::mem::take(&mut self.prefill_done)
    }

    /// Block until at least one chunked prefill completes (no-op when
    /// none are in flight).
    pub fn prefill_wait(&mut self) -> Vec<PrefillDone> {
        loop {
            self.prefill_advance();
            if !self.prefill_done.is_empty() || self.prefills.is_empty() {
                return std::mem::take(&mut self.prefill_done);
            }
            // Every job is mid-chunk on the pool: block on the oldest.
            let mut job = self.prefills.remove(0);
            match job.pending.take() {
                Some(t) => {
                    let res = t.wait();
                    self.prefill_step(job, res);
                }
                None => {
                    let result = Err(anyhow!("prefill job stalled without a pending chunk"));
                    self.prefill_done.push(PrefillDone { seq: job.seq, result });
                }
            }
        }
    }

    /// Abandon an in-flight (or completed-but-unclaimed) chunked
    /// prefill; the sequence comes back so its KV state drops with it.
    /// Any chunk still executing on a worker completes and is discarded.
    pub fn prefill_cancel(&mut self, id: u64) -> Option<Sequence> {
        if let Some(i) = self.prefills.iter().position(|j| j.seq.id == id) {
            let job = self.prefills.swap_remove(i);
            return Some(job.seq);
        }
        if let Some(i) = self.prefill_done.iter().position(|d| d.seq.id == id) {
            let done = self.prefill_done.swap_remove(i);
            return Some(done.seq);
        }
        None
    }

    /// Advance every in-flight prefill whose chunk has completed;
    /// returns how many phase transitions were made. Non-blocking.
    fn prefill_advance(&mut self) -> usize {
        let mut advanced = 0;
        let mut i = 0;
        while i < self.prefills.len() {
            let polled = match self.prefills[i].pending.as_ref() {
                Some(t) => t.try_wait(),
                None => None,
            };
            match polled {
                None => i += 1,
                Some(res) => {
                    let mut job = self.prefills.swap_remove(i);
                    job.pending = None;
                    self.prefill_step(job, res);
                    advanced += 1;
                    // don't advance `i`: swap_remove moved a fresh job here
                }
            }
        }
        advanced
    }

    /// Fold one completed chunk into its job: host-side phase work, then
    /// either the next chunk is submitted (job re-queued) or the prefill
    /// is complete/failed (pushed to the done buffer).
    fn prefill_step(&mut self, mut job: PrefillJob, res: Result<ExecDone>) {
        let done = match res {
            Ok(d) => d,
            Err(e) => {
                self.prefill_done.push(PrefillDone { seq: job.seq, result: Err(e) });
                return;
            }
        };
        self.stats.prefill_chunks += 1;
        if self.decode_active {
            self.stats.prefill_overlap_chunks += 1;
        }
        match self.prefill_phase(&mut job, done) {
            Ok(true) => self.prefills.push(job),
            Ok(false) => {
                let row = job.logits_row.take().expect("logits row present at completion");
                self.stats.prefills += 1;
                self.stats.prefill_secs += job.started.elapsed().as_secs_f64();
                self.prefill_done.push(PrefillDone { seq: job.seq, result: Ok(row) });
                self.sync_pool_stats();
            }
            Err(e) => self.prefill_done.push(PrefillDone { seq: job.seq, result: Err(e) }),
        }
    }

    /// The host-side half of one prefill phase. Returns `Ok(true)` when
    /// another chunk was submitted, `Ok(false)` when the prefill is
    /// complete (logits row buffered, speculation seeded).
    fn prefill_phase(&mut self, job: &mut PrefillJob, done: ExecDone) -> Result<bool> {
        let n_layers = self.cfg.n_layers;
        match job.phase {
            PrefillPhase::Embed => {
                let mut outputs = done.outputs;
                if outputs.is_empty() {
                    return Err(anyhow!("prefill embed returned no output"));
                }
                job.h = Some(outputs.remove(0));
                self.prefill_submit_layer(job, 0);
                Ok(true)
            }
            PrefillPhase::Layer(l) => {
                let mut it = done.outputs.into_iter();
                let h = it.next().ok_or_else(|| anyhow!("prefill layer output missing h"))?;
                let k = it
                    .next()
                    .ok_or_else(|| anyhow!("prefill layer output missing k"))?
                    .into_f32s()?;
                let v = it
                    .next()
                    .ok_or_else(|| anyhow!("prefill layer output missing v"))?
                    .into_f32s()?;
                let q_last = it
                    .next()
                    .ok_or_else(|| anyhow!("prefill layer output missing q_last"))?
                    .into_f32s()?;
                // recover pos/valid for the next layer chunk
                let mut inputs = done.inputs;
                let valid_t = inputs.pop().expect("valid tensor returned");
                let pos_t = inputs.pop().expect("pos tensor returned");
                job.pos_t = Some(pos_t);
                job.valid_t = Some(valid_t);
                job.h = Some(h);
                // populate GPU cache + offload completed pages (same
                // host work, same order as synchronous prefill).
                // `adopt_prefix` self-guards on len() != 0, so only the
                // first layer of the first chunk actually adopts.
                {
                    job.seq.kv.feed_tokens(&job.seq.tokens);
                    self.stats.prefill_tokens_saved +=
                        job.seq.kv.adopt_prefix() as u64;
                    let completed =
                        job.seq.kv.layers[l].gpu.load_prefill(&k, &v, job.len, job.bucket);
                    job.seq.kv.offload_completed(l, &completed, &mut job.seq.xfer);
                }
                job.q_last.push(q_last);
                if l + 1 < n_layers {
                    self.prefill_submit_layer(job, l + 1);
                } else {
                    let name = self.art(&format!("logits_t{}", job.bucket));
                    let args = vec![job.h.take().expect("hidden state present")];
                    let ticket = self.pool_submit(ExecJob::Logits { name, args });
                    job.pending = Some(ticket);
                    job.phase = PrefillPhase::Logits;
                }
                Ok(true)
            }
            PrefillPhase::Logits => {
                let lg = done
                    .outputs
                    .into_iter()
                    .next()
                    .ok_or_else(|| anyhow!("prefill logits output missing"))?
                    .into_f32s()?;
                let vocab = self.cfg.vocab;
                job.logits_row = Some(lg[(job.len - 1) * vocab..job.len * vocab].to_vec());
                self.prefill_submit_seed(job, 0);
                Ok(true)
            }
            PrefillPhase::Seed(l) => {
                let idx = done
                    .outputs
                    .get(1)
                    .ok_or_else(|| anyhow!("selection indices missing"))?
                    .i32s()?;
                let mask = done
                    .inputs
                    .get(3)
                    .ok_or_else(|| anyhow!("selection mask not returned"))?
                    .f32s()?;
                let sel = filter_selected(
                    idx,
                    mask,
                    self.cfg.n_kv,
                    self.cfg.n_pages_max(),
                    self.cfg.select_pages,
                );
                for (head, pages) in sel.iter().enumerate() {
                    let n = job.seq.kv.apply_selection(l, head, pages, &mut job.seq.xfer);
                    self.stats.recalled_pages += n as u64;
                }
                job.seq.spec[l].store(&job.q_last[l]);
                if l + 1 < n_layers {
                    self.prefill_submit_seed(job, l + 1);
                    Ok(true)
                } else {
                    Ok(false)
                }
            }
        }
    }

    /// Submit the next `layer_prefill` chunk for `job`.
    fn prefill_submit_layer(&mut self, job: &mut PrefillJob, l: usize) {
        let name = self.art(&format!("layer_prefill_t{}", job.bucket));
        let args = vec![
            job.h.take().expect("hidden state present"),
            job.pos_t.take().expect("pos tensor present"),
            job.valid_t.take().expect("valid tensor present"),
        ];
        let ticket = self.pool_submit(ExecJob::Prefill { name, layer: l, args });
        job.pending = Some(ticket);
        job.phase = PrefillPhase::Layer(l);
    }

    /// Submit the speculation-seeding selection (bucket 1) for layer `l`.
    fn prefill_submit_seed(&mut self, job: &mut PrefillJob, l: usize) {
        let (m, dh, qo, p) = (self.cfg.n_kv, self.cfg.d_head, self.cfg.n_qo, self.cfg.n_pages_max());
        let args = {
            let gpu = &job.seq.kv.layers[l].gpu;
            let (smin, smax) = gpu.summaries_sanitized();
            let mask = gpu.selectable_mask();
            vec![
                HostTensor::F32(job.q_last[l].clone(), vec![1, qo, dh]),
                HostTensor::F32(smin, vec![1, m, p, dh]),
                HostTensor::F32(smax, vec![1, m, p, dh]),
                HostTensor::F32(mask, vec![1, p]),
            ]
        };
        let name = self.art(&format!("select_{}_b1", self.params.variant.as_str()));
        let ticket = self.pool_submit(ExecJob::Selection { name, args });
        job.pending = Some(ticket);
        job.phase = PrefillPhase::Seed(l);
    }

    /// Submit a job on the executor pool (which must exist), counted in
    /// the engine stats like every pooled dispatch.
    fn pool_submit(&mut self, job: ExecJob) -> ExecTicket {
        self.stats.exec_jobs += 1;
        self.executor.as_ref().expect("executor pool active").submit(job)
    }

    /// Fold the runtime's and pool workers' cumulative compile /
    /// weight-upload counters into the engine stats (cheap: two atomics
    /// per worker).
    fn sync_pool_stats(&mut self) {
        let (mut compiled, mut uploads) = {
            let rt = self.rt.stats.borrow();
            (rt.compiled, rt.weight_uploads)
        };
        if let Some(pool) = &self.executor {
            let c = pool.counters();
            compiled += c.compiled;
            uploads += c.weight_uploads;
            let h = pool.health();
            self.stats.exec_respawns = h.respawns;
            self.stats.exec_retries = h.retries;
            self.stats.exec_dead_workers = h.workers.saturating_sub(h.alive) as u64;
        }
        if let Some(plan) = &self.faults {
            self.stats.faults_injected = plan.injected();
        }
        self.stats.exec_compiles = compiled;
        self.stats.weight_uploads = uploads;
        self.stats.sync_kv(&self.alloc.stats());
    }

    /// Take (or allocate) the batch gather tensors for this bucket.
    fn take_attn_scratch(
        &mut self,
        bucket: usize,
        m: usize,
        s: usize,
        dh: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let want_kv = bucket * m * s * dh;
        let want_valid = bucket * m * s;
        if let Some(pos) = self
            .attn_scratch
            .iter()
            .position(|(gk, _, gvalid)| gk.len() == want_kv && gvalid.len() == want_valid)
        {
            return self.attn_scratch.swap_remove(pos);
        }
        (vec![0.0; want_kv], vec![0.0; want_kv], vec![0.0; want_valid])
    }

    /// Take (or allocate) a selection scratch for this bucket:
    /// [q, smin, smax, mask] in the select artifact's argument order.
    fn take_sel_scratch(&mut self, bucket: usize) -> SelScratch {
        if let Some(pos) = self.sel_scratch.iter().position(|sc| sc.bucket == bucket) {
            return self.sel_scratch.swap_remove(pos);
        }
        let (m, dh, qo, p) =
            (self.cfg.n_kv, self.cfg.d_head, self.cfg.n_qo, self.cfg.n_pages_max());
        SelScratch {
            bucket,
            args: vec![
                HostTensor::F32(vec![0.0; bucket * qo * dh], vec![bucket, qo, dh]),
                HostTensor::F32(vec![0.0; bucket * m * p * dh], vec![bucket, m, p, dh]),
                HostTensor::F32(vec![0.0; bucket * m * p * dh], vec![bucket, m, p, dh]),
                HostTensor::F32(vec![0.0; bucket * p], vec![bucket, p]),
            ],
        }
    }

    /// Selection for a single sequence (prefill seeding path, bucket 1).
    fn run_selection_single(
        &mut self,
        seq: &mut Sequence,
        layer: usize,
        q: &[f32],
    ) -> Result<Vec<Vec<usize>>> {
        let cfg = &self.cfg;
        let (m, dh, qo, p) = (cfg.n_kv, cfg.d_head, cfg.n_qo, cfg.n_pages_max());
        let gpu = &seq.kv.layers[layer].gpu;
        let (smin, smax) = gpu.summaries_sanitized();
        let mask = gpu.selectable_mask();
        let variant = self.params.variant.as_str();
        let out = self.rt.run(
            &self.art(&format!("select_{}_b1", variant)),
            &[
                HostTensor::F32(q.to_vec(), vec![1, qo, dh]),
                HostTensor::F32(smin, vec![1, m, p, dh]),
                HostTensor::F32(smax, vec![1, m, p, dh]),
                HostTensor::F32(mask.clone(), vec![1, p]),
            ],
            None,
        )?;
        let idx = out[1].i32s()?;
        Ok(filter_selected(idx, &mask, m, p, cfg.select_pages))
    }

    /// Convenience: generate to completion for a single sequence.
    pub fn generate(&mut self, seq: &mut Sequence) -> Result<()> {
        let lg = self.prefill(seq)?;
        let params = seq.sample.clone();
        let tok = sample_token(&lg, &params, &mut seq.rng);
        seq.tokens.push(tok);
        if Some(tok) == seq.eos {
            seq.finished = true;
        }
        while !seq.done() {
            let mut batch = [&mut *seq];
            self.decode_step(&mut batch)?;
        }
        Ok(())
    }
}

impl Backend for Engine {
    fn model(&self) -> &ModelConfig {
        &self.cfg
    }

    fn new_sequence(
        &self,
        id: u64,
        prompt: Vec<i32>,
        max_new: usize,
        sample: SampleParams,
    ) -> Sequence {
        Engine::new_sequence(self, id, prompt, max_new, sample)
    }

    fn prefill(&mut self, seq: &mut Sequence) -> Result<Vec<f32>> {
        Engine::prefill(self, seq)
    }

    fn prefill_begin(&mut self, seq: Sequence) -> Option<PrefillDone> {
        Engine::prefill_begin(self, seq)
    }

    fn prefill_poll(&mut self) -> Vec<PrefillDone> {
        Engine::prefill_poll(self)
    }

    fn prefill_wait(&mut self) -> Vec<PrefillDone> {
        Engine::prefill_wait(self)
    }

    fn prefills_inflight(&self) -> usize {
        self.prefills.len() + self.prefill_done.len()
    }

    fn prefill_cancel(&mut self, id: u64) -> Option<Sequence> {
        Engine::prefill_cancel(self, id)
    }

    fn decode_step(&mut self, seqs: &mut [&mut Sequence]) -> Result<()> {
        Engine::decode_step(self, seqs)
    }

    fn decode_step_lanes(&mut self, lanes: &mut [Vec<&mut Sequence>]) -> Result<()> {
        Engine::decode_step_lanes(self, lanes)
    }

    fn retire_sequence(&mut self, seq: &mut Sequence) {
        self.drain_sequence(seq);
    }

    fn kv_admit(&mut self, id: u64, prompt_tokens: usize, max_new: usize) -> AdmitDecision {
        let footprint = worst_case_pages(&self.cfg, prompt_tokens.saturating_add(max_new));
        self.alloc.try_reserve(id, footprint)
    }

    fn kv_release(&mut self, id: u64) {
        self.alloc.release_reservation(id);
    }

    fn kv_stats(&self) -> KvPoolStats {
        self.alloc.stats()
    }

    fn stats(&self) -> &EngineStats {
        &self.stats
    }
}

/// The lane-containment fold shared by the `Backend::decode_step_lanes`
/// default impl and the engine's serial-dispatch fallback: every lane
/// is driven even when one fails (its sequences simply don't advance
/// this step), and the first error returns only once all lanes ran.
fn contain_lanes<T>(
    lanes: impl IntoIterator<Item = T>,
    mut step: impl FnMut(T) -> Result<()>,
) -> Result<()> {
    let mut first_err = None;
    for lane in lanes {
        if let Err(e) = step(lane) {
            if first_err.is_none() {
                first_err = Some(e);
            }
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Post-filter one sequence's raw selection indices: per kv head, drop
/// padded / non-selectable pages. Shared by the synchronous seeding
/// path and the pooled prefill Seed phase so they cannot diverge.
fn filter_selected(
    idx: &[i32],
    mask: &[f32],
    n_kv: usize,
    n_pages: usize,
    k_sel: usize,
) -> Vec<Vec<usize>> {
    (0..n_kv)
        .map(|head| {
            idx[head * k_sel..(head + 1) * k_sel]
                .iter()
                .map(|&x| x as usize)
                .filter(|&pg| pg < n_pages && mask[pg] > 0.0)
                .collect()
        })
        .collect()
}

/// Temperature + nucleus sampling (greedy when temperature == 0).
pub fn sample_token(logits: &[f32], p: &SampleParams, rng: &mut Rng) -> i32 {
    if p.temperature <= 0.0 {
        return crate::linalg::argmax(logits) as i32;
    }
    let mut probs: Vec<f32> = logits.iter().map(|&x| x / p.temperature).collect();
    crate::linalg::softmax_inplace(&mut probs);
    if p.top_p < 1.0 {
        truncate_top_p(&mut probs, p.top_p);
    }
    rng.categorical(&probs) as i32
}

/// Zero every probability outside the nucleus: the shortest prefix of
/// the (probability-descending, index-ascending on ties) order whose
/// mass reaches `top_p`. Uses partial selection with a doubling
/// candidate set instead of sorting the whole vocabulary — the nucleus
/// is tiny compared to V, so this is O(V + c log c) per call instead of
/// O(V log V), and it needs no auxiliary hash set.
fn truncate_top_p(probs: &mut [f32], top_p: f32) {
    let v = probs.len();
    if v == 0 {
        return;
    }
    let cmp = |a: &usize, b: &usize| {
        probs[*b]
            .partial_cmp(&probs[*a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(b))
    };
    let mut order: Vec<usize> = (0..v).collect();
    let mut k = 64.min(v);
    let cut = loop {
        if k < v {
            order.select_nth_unstable_by(k - 1, cmp);
        }
        order[..k].sort_unstable_by(cmp);
        let mut acc = 0.0f32;
        let mut cut = None;
        for (rank, &i) in order[..k].iter().enumerate() {
            acc += probs[i];
            if acc >= top_p {
                cut = Some(rank + 1);
                break;
            }
        }
        match cut {
            Some(c) => break c,
            // numerical shortfall: the whole distribution is the nucleus
            None if k == v => break v,
            None => k = (k * 2).min(v),
        }
    };
    for &i in &order[cut..] {
        probs[i] = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The seed's straightforward implementation (full vocab sort + hash
    /// set), kept as the behavioural reference for the optimized path.
    fn sample_token_reference(logits: &[f32], p: &SampleParams, rng: &mut Rng) -> i32 {
        if p.temperature <= 0.0 {
            return crate::linalg::argmax(logits) as i32;
        }
        let mut probs: Vec<f32> = logits.iter().map(|&x| x / p.temperature).collect();
        crate::linalg::softmax_inplace(&mut probs);
        if p.top_p < 1.0 {
            let mut order: Vec<usize> = (0..probs.len()).collect();
            order.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
            let mut acc = 0.0f32;
            let mut cut = probs.len();
            for (rank, &i) in order.iter().enumerate() {
                acc += probs[i];
                if acc >= p.top_p {
                    cut = rank + 1;
                    break;
                }
            }
            let keep: std::collections::HashSet<usize> = order[..cut].iter().cloned().collect();
            for (i, pr) in probs.iter_mut().enumerate() {
                if !keep.contains(&i) {
                    *pr = 0.0;
                }
            }
        }
        rng.categorical(&probs) as i32
    }

    #[test]
    fn nucleus_sampling_matches_reference_for_fixed_seeds() {
        let mut gen = Rng::new(0xBEEF);
        for case in 0..200u64 {
            let vocab = 1 + gen.below(300);
            let logits: Vec<f32> = (0..vocab).map(|_| gen.normal_f32(0.0, 3.0)).collect();
            let p = SampleParams {
                temperature: 0.25 + gen.f32() * 1.5,
                top_p: [0.1f32, 0.5, 0.9, 0.95, 0.999, 1.0][gen.below(6)],
                seed: case,
            };
            let mut r1 = Rng::new(case);
            let mut r2 = Rng::new(case);
            let a = sample_token(&logits, &p, &mut r1);
            let b = sample_token_reference(&logits, &p, &mut r2);
            assert_eq!(a, b, "case {} vocab {} top_p {}", case, vocab, p.top_p);
            // identical RNG consumption, so downstream draws stay aligned
            assert_eq!(r1.next_u64(), r2.next_u64(), "rng stream diverged at case {}", case);
        }
    }

    #[test]
    fn nucleus_growth_past_initial_candidate_set() {
        // near-uniform distribution with top_p close to 1 forces the
        // doubling loop well past the initial 64 candidates.
        let logits = vec![0.0f32; 4096];
        let p = SampleParams { temperature: 1.0, top_p: 0.999, seed: 1 };
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let a = sample_token(&logits, &p, &mut r1);
        let b = sample_token_reference(&logits, &p, &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    fn greedy_ignores_rng() {
        let logits = vec![0.1f32, 2.0, -1.0];
        let mut rng = Rng::new(4);
        assert_eq!(sample_token(&logits, &SampleParams::greedy(), &mut rng), 1);
    }
}
