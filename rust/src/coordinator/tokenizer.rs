//! Byte-level tokenizer: 256 byte tokens + BOS/EOS/PAD/SEP specials.
//! The synthetic-weight models use vocab 260 to match.

/// Beginning-of-sequence token.
pub const BOS: i32 = 256;
/// End-of-sequence token.
pub const EOS: i32 = 257;
/// Padding token.
pub const PAD: i32 = 258;
/// Separator token.
pub const SEP: i32 = 259;
/// Vocabulary size (256 byte tokens + 4 specials).
pub const VOCAB: usize = 260;

/// Encode text as BOS + bytes.
pub fn encode(text: &str) -> Vec<i32> {
    let mut out = Vec::with_capacity(text.len() + 1);
    out.push(BOS);
    out.extend(text.bytes().map(|b| b as i32));
    out
}

/// Decode tokens back to text (specials are dropped; invalid UTF-8 is
/// rendered lossily).
pub fn decode(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .filter(|&&t| (0..256).contains(&t))
        .map(|&t| t as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let text = "FreeKV: speculative retrieval!";
        let toks = encode(text);
        assert_eq!(toks[0], BOS);
        assert_eq!(toks.len(), text.len() + 1);
        assert_eq!(decode(&toks), text);
    }

    #[test]
    fn specials_dropped_on_decode() {
        assert_eq!(decode(&[BOS, 104, 105, EOS, PAD, SEP]), "hi");
    }

    #[test]
    fn all_tokens_in_vocab() {
        for t in encode("any text ~ \u{00ff}") {
            assert!((0..VOCAB as i32).contains(&t));
        }
    }
}
