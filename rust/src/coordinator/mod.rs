//! Layer-3 coordinator: the real serving runtime, event-driven end to
//! end. The [`engine::Engine`] executes the decode pipeline over AOT
//! artifacts (speculative retrieval + correction); the
//! [`scheduler::Scheduler`] is the pure continuous-batching policy core
//! that reports every sampled token as a [`scheduler::StepEvent`]; the
//! [`engine_loop::EngineLoop`] owns the engine thread and fans those
//! events out to per-session channels, giving clients a cloneable
//! [`engine_loop::Submitter`] with bounded admission and a
//! [`engine_loop::SessionHandle`] with streaming events and mid-flight
//! cancellation. [`sim_backend::SimBackend`] swaps in for the engine
//! where artifacts/PJRT are unavailable. Above the loop, the
//! [`router`] tier scales serving out to N engine-loop replicas behind
//! one [`router::Router`] seam — KV-pressure balancing with
//! prefix-affinity dispatch, plus round-robin and single-replica
//! ablations — which is what the HTTP edge actually talks to.

pub mod engine;
pub mod engine_loop;
pub mod metrics;
pub mod router;
pub mod scheduler;
pub mod sim_backend;
pub mod tokenizer;

pub use engine::{Backend, Engine, EngineStats, SampleParams, Sequence};
pub use engine_loop::{EngineLoop, LoopConfig, SessionEvent, SessionHandle, SubmitError, Submitter};
pub use metrics::{Metrics, RequestTiming};
pub use router::{
    DispatchPolicy, KvAwareRouter, KvRouterConfig, ReplicaLoad, ReplicaSet, RoundRobinRouter,
    Router, RouterCounters, RouterKind, SingleRouter,
};
pub use scheduler::{Completion, FinishReason, Request, Scheduler, SchedulerConfig, StepEvent};
pub use sim_backend::SimBackend;
