//! Layer-3 coordinator: the real serving runtime. Engine (decode pipeline
//! over AOT artifacts with speculative retrieval + correction), byte
//! tokenizer, serving metrics, and the continuous-batching scheduler.

pub mod engine;
pub mod metrics;
pub mod scheduler;
pub mod tokenizer;

pub use engine::{Engine, EngineStats, SampleParams, Sequence};
pub use metrics::{Metrics, RequestTiming};
pub use scheduler::{Completion, Request, Scheduler, SchedulerConfig};
