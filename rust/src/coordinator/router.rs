//! Multi-replica router tier: the seam between the HTTP edge and N
//! [`EngineLoop`] replicas.
//!
//! The server no longer talks to a bare [`Submitter`]; it talks to a
//! [`Router`], and a bare `Submitter` *is* the single-replica router
//! (today's path, bit-identical). [`ReplicaSet`] spawns and owns N
//! engine loops — each with its own scheduler, backend, and KV page
//! allocator, so no allocator lock is ever contended across replicas —
//! and hands out routers over their submitters:
//!
//! * [`SingleRouter`] — N=1 passthrough, the ablation baseline equal to
//!   the pre-router stack.
//! * [`RoundRobinRouter`] — strict rotation over live replicas,
//!   ignoring load and prefix affinity (the routing ablation).
//! * [`KvAwareRouter`] — the production policy: new requests go to the
//!   replica with the lowest combined queue depth + KV-page pressure,
//!   while requests whose prompt shares a prefix with earlier traffic
//!   are steered to the replica whose retained tier already holds those
//!   pages. Affinity is tracked in a small router-side map from prefix
//!   chain hashes (the same per-page boundary hashes `RequestKv`
//!   records, so a map hit predicts a retained-tier adoption) to the
//!   replica that last served them, bounded FIFO with eviction on
//!   capacity. A bounded imbalance factor overrides affinity when it
//!   would overload one replica.
//!
//! Health and failure aggregate across the set: one dead replica is
//! routed around and reported `degraded`; only when every replica is
//! down does the router report `down` and refuse with
//! [`SubmitError::Closed`]. [`Router::drain`] fans one shared deadline
//! out to every replica, so SIGINT/SIGTERM drains the whole set at
//! once. Cancellation needs no routing: a [`SessionHandle`] carries its
//! own channel to the replica that admitted it.
//!
//! The routing policy itself ([`DispatchPolicy`]) is a pure function of
//! per-replica load snapshots ([`ReplicaLoad`]), shared between the
//! live routers here and the deterministic tick-level loadtest driver
//! in [`crate::workload::run_router_loadtest`] — the bench sweeps and
//! the serving path exercise the exact same scoring and affinity code.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::engine::Backend;
use crate::coordinator::engine_loop::{
    EngineLoop, Health, LoopConfig, SessionHandle, SubmitError, Submitter,
};
use crate::coordinator::scheduler::{Request, Scheduler};
use crate::kvcache::alloc::{fnv1a_i32, fold_key, mix2_i32, FNV_OFFSET, MIX2_SEED};

/// Lock a mutex, recovering the value from a poisoned lock (a panicking
/// connection thread must not wedge routing for everyone else).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// The dispatch surface the HTTP edge needs from the serving tier,
/// whether it is one engine loop or many. A bare [`Submitter`]
/// implements it as the single-replica identity, so `serve_listener`
/// callers that pass `el.submitter()` keep today's behaviour exactly.
pub trait Router: Send + Sync {
    /// Dispatch a request to some replica. Multi-replica routers retry
    /// the remaining live replicas when the chosen one refuses with
    /// [`SubmitError::Closed`], so a single dead replica never turns
    /// into a client-visible engine-down error.
    fn submit(&self, req: Request) -> Result<SessionHandle, SubmitError>;

    /// Aggregated serving health: `Ok` when every replica is healthy,
    /// `Degraded` while any replica is degraded or down but at least
    /// one still serves, `Down` when none do.
    fn health(&self) -> Health;

    /// Serving metrics. Single-replica routers return the engine loop's
    /// one-line report unchanged; multi-replica routers return an
    /// aggregate router line followed by one `replica<i> ...` labelled
    /// line per replica. `Err` only when every replica is gone.
    fn metrics_report(&self) -> Result<String, SubmitError>;

    /// Sessions currently queued or running across all replicas.
    fn in_flight(&self) -> usize;

    /// Aggregate admission capacity (the HTTP edge sizes its
    /// connection-thread budget from this).
    fn queue_cap(&self) -> usize;

    /// Begin a graceful drain on every replica under one shared
    /// deadline: new submissions are refused immediately, in-flight
    /// sessions finish until `timeout` from now.
    fn drain(&self, timeout: Duration);

    /// Number of engine-loop replicas behind this router.
    fn replicas(&self) -> usize;
}

impl Router for Submitter {
    fn submit(&self, req: Request) -> Result<SessionHandle, SubmitError> {
        Submitter::submit(self, req)
    }

    fn health(&self) -> Health {
        Submitter::health(self)
    }

    fn metrics_report(&self) -> Result<String, SubmitError> {
        Submitter::metrics_report(self)
    }

    fn in_flight(&self) -> usize {
        Submitter::in_flight(self)
    }

    fn queue_cap(&self) -> usize {
        Submitter::queue_cap(self)
    }

    fn drain(&self, timeout: Duration) {
        Submitter::drain(self, timeout)
    }

    fn replicas(&self) -> usize {
        1
    }
}

impl<T: Router + ?Sized> Router for Arc<T> {
    fn submit(&self, req: Request) -> Result<SessionHandle, SubmitError> {
        (**self).submit(req)
    }

    fn health(&self) -> Health {
        (**self).health()
    }

    fn metrics_report(&self) -> Result<String, SubmitError> {
        (**self).metrics_report()
    }

    fn in_flight(&self) -> usize {
        (**self).in_flight()
    }

    fn queue_cap(&self) -> usize {
        (**self).queue_cap()
    }

    fn drain(&self, timeout: Duration) {
        (**self).drain(timeout)
    }

    fn replicas(&self) -> usize {
        (**self).replicas()
    }
}

/// Compute the page-boundary prefix chain hashes of a prompt — the
/// exact keys `RequestKv::feed_tokens` snapshots (FNV-1a and a
/// splitmix-style mixer chained per token from [`FNV_OFFSET`] /
/// [`MIX2_SEED`], folded at every `page_size` boundary), so an affinity
/// map keyed on these predicts which replica's prefix cache can adopt
/// the prompt's pages.
pub fn prefix_boundary_hashes(prompt: &[i32], page_size: usize) -> Vec<u128> {
    if page_size == 0 {
        return Vec::new();
    }
    let (mut fnv, mut mix) = (FNV_OFFSET, MIX2_SEED);
    let mut out = Vec::with_capacity(prompt.len() / page_size);
    for (i, &tok) in prompt.iter().enumerate() {
        fnv = fnv1a_i32(fnv, tok);
        mix = mix2_i32(mix, tok);
        if (i + 1) % page_size == 0 {
            out.push(fold_key(fnv, mix));
        }
    }
    out
}

/// Live load signals of one replica, however it is hosted (engine loop
/// or bare scheduler in the tick-level loadtest).
#[derive(Debug, Clone, Copy)]
pub struct ReplicaLoad {
    /// Whether the replica is serving (false routes around it).
    pub alive: bool,
    /// Sessions queued or running on the replica.
    pub in_flight: usize,
    /// Distinct KV pool pages the replica's allocator currently holds.
    pub kv_pages_used: u64,
}

/// Tuning knobs of the KV-aware dispatch policy.
#[derive(Debug, Clone)]
pub struct KvRouterConfig {
    /// Page-boundary stride of the affinity hashes; must match the
    /// backend's `ModelConfig::page_size` for map hits to predict
    /// prefix-cache adoptions ([`ReplicaSet::kv_router`] reads it from
    /// replica 0 automatically).
    pub page_size: usize,
    /// Max boundary-hash entries in the affinity map; oldest entries
    /// are evicted FIFO past this.
    pub affinity_cap: usize,
    /// Bounded imbalance factor: an affinity dispatch is overridden
    /// (falling back to least-loaded) when it would leave the target's
    /// queue depth above `imbalance * (least_loaded_depth + 1)`.
    pub imbalance: f64,
    /// Weight of relative KV-page pressure against queue depth in the
    /// least-loaded score (pressure is normalized to `[0, 1]` across
    /// replicas, so this is in units of queue slots).
    pub kv_weight: f64,
    /// Live routers refresh each replica's cached KV-page gauge every
    /// this many submissions (an `EngineStats` round-trip per replica;
    /// queue depth is an atomic read and always fresh).
    pub stats_every: u64,
}

impl Default for KvRouterConfig {
    fn default() -> Self {
        KvRouterConfig {
            // the sim backend's page size; real deployments read theirs
            // via ReplicaSet::kv_router
            page_size: 4,
            affinity_cap: 4096,
            imbalance: 2.0,
            kv_weight: 1.0,
            stats_every: 8,
        }
    }
}

/// Cumulative counters of one dispatch policy's routing decisions.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RouterCounters {
    /// Routes whose deepest boundary hash was found in the affinity map
    /// pointing at a live replica.
    pub affinity_hits: u64,
    /// Routes with no usable affinity entry (dispatched least-loaded).
    pub affinity_misses: u64,
    /// Affinity hits overridden by the bounded imbalance factor.
    pub affinity_reroutes: u64,
    /// Affinity entries evicted by the FIFO capacity bound.
    pub affinity_evictions: u64,
}

struct AffinityEntry {
    replica: usize,
    stamp: u64,
}

/// The KV-aware routing policy core: pure state + scoring over
/// [`ReplicaLoad`] snapshots, with no engine-loop plumbing — shared
/// verbatim between [`KvAwareRouter`] and the tick-level loadtest
/// driver so benches measure the exact policy the server runs.
pub struct KvDispatchState {
    cfg: KvRouterConfig,
    affinity: HashMap<u128, AffinityEntry>,
    /// FIFO insertion order of affinity keys; one slot per live map
    /// entry (re-records update the entry in place, keeping its slot).
    order: VecDeque<(u128, u64)>,
    stamp: u64,
    counters: RouterCounters,
}

impl KvDispatchState {
    /// Fresh policy state.
    pub fn new(cfg: KvRouterConfig) -> KvDispatchState {
        KvDispatchState {
            cfg,
            affinity: HashMap::new(),
            order: VecDeque::new(),
            stamp: 0,
            counters: RouterCounters::default(),
        }
    }

    /// Least-loaded live replica by queue depth + weighted relative KV
    /// pressure; ties break to the lowest index (deterministic).
    fn least_loaded(&self, loads: &[ReplicaLoad]) -> Option<usize> {
        let max_kv = loads
            .iter()
            .filter(|l| l.alive)
            .map(|l| l.kv_pages_used)
            .max()
            .unwrap_or(0)
            .max(1);
        let score = |l: &ReplicaLoad| {
            l.in_flight as f64 + self.cfg.kv_weight * (l.kv_pages_used as f64 / max_kv as f64)
        };
        loads
            .iter()
            .enumerate()
            .filter(|(_, l)| l.alive)
            .min_by(|(_, a), (_, b)| {
                score(a).partial_cmp(&score(b)).unwrap_or(std::cmp::Ordering::Equal)
            })
            .map(|(i, _)| i)
    }

    /// Pick a replica for `prompt` given the current loads. `None` only
    /// when no replica is alive. Affinity first: the deepest boundary
    /// hash present in the map wins (the longest already-cached prefix);
    /// an entry pointing at a dead replica is treated as a miss (and is
    /// overwritten at the next [`KvDispatchState::record`]). The
    /// bounded imbalance factor then compares queue depths only — KV
    /// pressure steers the least-loaded choice but must not repel
    /// affinity from the very replica whose retained pages raise it.
    pub fn route(&mut self, prompt: &[i32], loads: &[ReplicaLoad]) -> Option<usize> {
        let least = self.least_loaded(loads)?;
        let mut target = None;
        for h in prefix_boundary_hashes(prompt, self.cfg.page_size).into_iter().rev() {
            if let Some(e) = self.affinity.get(&h) {
                if loads.get(e.replica).map_or(false, |l| l.alive) {
                    target = Some(e.replica);
                }
                break;
            }
        }
        match target {
            Some(t) => {
                self.counters.affinity_hits += 1;
                let bound = self.cfg.imbalance.max(1.0) * (loads[least].in_flight as f64 + 1.0);
                if t != least && (loads[t].in_flight as f64 + 1.0) > bound {
                    self.counters.affinity_reroutes += 1;
                    Some(least)
                } else {
                    Some(t)
                }
            }
            None => {
                self.counters.affinity_misses += 1;
                Some(least)
            }
        }
    }

    /// Record that `prompt` was dispatched to `replica`: every boundary
    /// hash of the prompt now maps there (its pages will land in — or
    /// already live in — that replica's prefix cache). Existing entries
    /// are updated in place; new keys join the FIFO order and the
    /// oldest are evicted past `affinity_cap`.
    pub fn record(&mut self, prompt: &[i32], replica: usize) {
        for h in prefix_boundary_hashes(prompt, self.cfg.page_size) {
            match self.affinity.entry(h) {
                std::collections::hash_map::Entry::Occupied(mut e) => {
                    e.get_mut().replica = replica;
                }
                std::collections::hash_map::Entry::Vacant(v) => {
                    self.stamp += 1;
                    v.insert(AffinityEntry { replica, stamp: self.stamp });
                    self.order.push_back((h, self.stamp));
                }
            }
        }
        while self.affinity.len() > self.cfg.affinity_cap.max(1) {
            let Some((h, s)) = self.order.pop_front() else { break };
            if self.affinity.get(&h).map_or(false, |e| e.stamp == s) {
                self.affinity.remove(&h);
                self.counters.affinity_evictions += 1;
            }
        }
    }

    /// Routing-decision counters so far.
    pub fn counters(&self) -> RouterCounters {
        self.counters
    }

    /// Live affinity-map entries (bounded by `affinity_cap`).
    pub fn affinity_len(&self) -> usize {
        self.affinity.len()
    }
}

/// A dispatch policy over per-replica load snapshots: the pure routing
/// core shared by the live routers and the tick-level loadtest driver.
pub enum DispatchPolicy {
    /// Strict rotation over live replicas — ignores load and prefix
    /// affinity (the routing ablation).
    RoundRobin {
        /// Next rotation index (monotone, wrapped mod replica count).
        next: usize,
    },
    /// KV-pressure + prefix-affinity routing (the production policy).
    KvAware(KvDispatchState),
}

impl DispatchPolicy {
    /// The round-robin ablation policy.
    pub fn round_robin() -> DispatchPolicy {
        DispatchPolicy::RoundRobin { next: 0 }
    }

    /// The KV-aware production policy.
    pub fn kv_aware(cfg: KvRouterConfig) -> DispatchPolicy {
        DispatchPolicy::KvAware(KvDispatchState::new(cfg))
    }

    /// Parse a `--router` CLI name (`kv`/`kv-aware`,
    /// `round-robin`/`rr`). `page_size` seeds the KV policy's boundary
    /// hashing and must match the backend's.
    pub fn parse(name: &str, page_size: usize) -> Option<DispatchPolicy> {
        Some(match name {
            "kv" | "kv-aware" | "kvaware" => {
                DispatchPolicy::kv_aware(KvRouterConfig { page_size, ..Default::default() })
            }
            "round-robin" | "roundrobin" | "rr" => DispatchPolicy::round_robin(),
            _ => return None,
        })
    }

    /// Stable policy name (metrics label / bench row key).
    pub fn name(&self) -> &'static str {
        match self {
            DispatchPolicy::RoundRobin { .. } => "round-robin",
            DispatchPolicy::KvAware(_) => "kv",
        }
    }

    /// Pick a replica for `prompt`; `None` only when no replica is
    /// alive.
    pub fn route(&mut self, prompt: &[i32], loads: &[ReplicaLoad]) -> Option<usize> {
        match self {
            DispatchPolicy::RoundRobin { next } => {
                let n = loads.len();
                for k in 0..n {
                    let i = (*next + k) % n;
                    if loads[i].alive {
                        *next = (i + 1) % n;
                        return Some(i);
                    }
                }
                None
            }
            DispatchPolicy::KvAware(state) => state.route(prompt, loads),
        }
    }

    /// Record the replica a prompt was actually dispatched to (no-op
    /// for round-robin).
    pub fn record(&mut self, prompt: &[i32], replica: usize) {
        if let DispatchPolicy::KvAware(state) = self {
            state.record(prompt, replica);
        }
    }

    /// Routing-decision counters (all zero for round-robin).
    pub fn counters(&self) -> RouterCounters {
        match self {
            DispatchPolicy::RoundRobin { .. } => RouterCounters::default(),
            DispatchPolicy::KvAware(state) => state.counters(),
        }
    }
}

/// Aggregate health over a replica set: all down → `Down`; any down or
/// degraded (with at least one serving) → `Degraded`; else `Ok`.
fn aggregate_health(replicas: &[Submitter]) -> Health {
    let mut alive = 0usize;
    let mut degraded = false;
    for s in replicas {
        match s.health() {
            Health::Ok => alive += 1,
            Health::Degraded => {
                alive += 1;
                degraded = true;
            }
            Health::Down => degraded = true,
        }
    }
    if alive == 0 {
        Health::Down
    } else if degraded {
        Health::Degraded
    } else {
        Health::Ok
    }
}

/// Multi-replica metrics: one aggregate `router=...` line, then one
/// `replica<i> ...` labelled line per replica (a dead replica reports
/// only `health=down`). `Err(Closed)` when every replica is gone, so
/// the edge's engine-down latch fires exactly when nothing serves.
fn aggregate_report(
    kind: &str,
    extra: &str,
    replicas: &[Submitter],
) -> Result<String, SubmitError> {
    let mut rows = Vec::with_capacity(replicas.len() + 1);
    let mut alive = 0usize;
    for (i, s) in replicas.iter().enumerate() {
        match s.metrics_report() {
            Ok(r) => {
                alive += 1;
                rows.push(format!("replica{} {}", i, r));
            }
            Err(_) => rows.push(format!("replica{} health=down", i)),
        }
    }
    if alive == 0 {
        return Err(SubmitError::Closed);
    }
    let head = format!(
        "router={} replicas={} alive={}{} health={}",
        kind,
        replicas.len(),
        alive,
        extra,
        aggregate_health(replicas).as_str()
    );
    let mut out = head;
    for row in rows {
        out.push('\n');
        out.push_str(&row);
    }
    Ok(out)
}

/// Fan one shared drain deadline out to every replica.
fn drain_all(replicas: &[Submitter], timeout: Duration) {
    let deadline = Instant::now() + timeout;
    for s in replicas {
        s.drain_until(deadline);
    }
}

/// Route `req` with `policy` over `loads`, then submit: the routed
/// replica first, then every other live replica by ascending queue
/// depth. `Closed` from one replica routes around it; `Busy` is
/// remembered and returned only when every live replica is busy;
/// `Draining` propagates (drains are router-wide). The policy records
/// the replica that actually admitted the request.
fn dispatch(
    replicas: &[Submitter],
    policy: &Mutex<DispatchPolicy>,
    loads: &[ReplicaLoad],
    req: Request,
) -> Result<SessionHandle, SubmitError> {
    let Some(first) = lock(policy).route(&req.prompt, loads) else {
        return Err(SubmitError::Closed);
    };
    let mut order = vec![first];
    let mut rest: Vec<usize> =
        (0..replicas.len()).filter(|&i| i != first && loads[i].alive).collect();
    rest.sort_by_key(|&i| loads[i].in_flight);
    order.extend(rest);
    let mut busy = None;
    for &i in &order {
        match replicas[i].submit(req.clone()) {
            Ok(h) => {
                lock(policy).record(&req.prompt, i);
                return Ok(h);
            }
            Err(e @ SubmitError::Busy { .. }) => {
                busy.get_or_insert(e);
            }
            Err(SubmitError::Draining) => return Err(SubmitError::Draining),
            Err(SubmitError::Closed) => {}
        }
    }
    Err(busy.unwrap_or(SubmitError::Closed))
}

/// N=1 passthrough router: today's single-`Submitter` path with a
/// router-shaped type. Responses, metrics, and health are bit-identical
/// to serving the submitter directly.
#[derive(Clone)]
pub struct SingleRouter {
    replica: Submitter,
}

impl SingleRouter {
    /// Wrap the one replica's submitter.
    pub fn new(replica: Submitter) -> SingleRouter {
        SingleRouter { replica }
    }
}

impl Router for SingleRouter {
    fn submit(&self, req: Request) -> Result<SessionHandle, SubmitError> {
        self.replica.submit(req)
    }

    fn health(&self) -> Health {
        self.replica.health()
    }

    fn metrics_report(&self) -> Result<String, SubmitError> {
        self.replica.metrics_report()
    }

    fn in_flight(&self) -> usize {
        self.replica.in_flight()
    }

    fn queue_cap(&self) -> usize {
        self.replica.queue_cap()
    }

    fn drain(&self, timeout: Duration) {
        self.replica.drain(timeout)
    }

    fn replicas(&self) -> usize {
        1
    }
}

/// Strict-rotation ablation router: live replicas take turns, with no
/// load or affinity signal. Dead replicas are skipped.
#[derive(Clone)]
pub struct RoundRobinRouter {
    replicas: Arc<Vec<Submitter>>,
    policy: Arc<Mutex<DispatchPolicy>>,
}

impl RoundRobinRouter {
    /// Rotate over `replicas` (panics if empty).
    pub fn new(replicas: Vec<Submitter>) -> RoundRobinRouter {
        assert!(!replicas.is_empty(), "router needs at least one replica");
        RoundRobinRouter {
            replicas: Arc::new(replicas),
            policy: Arc::new(Mutex::new(DispatchPolicy::round_robin())),
        }
    }

    fn loads(&self) -> Vec<ReplicaLoad> {
        self.replicas
            .iter()
            .map(|s| ReplicaLoad {
                alive: s.health() != Health::Down,
                in_flight: s.in_flight(),
                kv_pages_used: 0,
            })
            .collect()
    }
}

impl Router for RoundRobinRouter {
    fn submit(&self, req: Request) -> Result<SessionHandle, SubmitError> {
        dispatch(&self.replicas, &self.policy, &self.loads(), req)
    }

    fn health(&self) -> Health {
        aggregate_health(&self.replicas)
    }

    fn metrics_report(&self) -> Result<String, SubmitError> {
        aggregate_report("round-robin", "", &self.replicas)
    }

    fn in_flight(&self) -> usize {
        self.replicas.iter().map(|s| s.in_flight()).sum()
    }

    fn queue_cap(&self) -> usize {
        self.replicas.iter().map(|s| s.queue_cap()).sum()
    }

    fn drain(&self, timeout: Duration) {
        drain_all(&self.replicas, timeout)
    }

    fn replicas(&self) -> usize {
        self.replicas.len()
    }
}

/// Cached per-replica KV-page gauges: queue depth is an atomic read per
/// submit, but `kv_pages_used` needs an `EngineStats` round-trip to
/// each loop, so it refreshes every `every` submissions.
struct PressureCache {
    pages: Vec<u64>,
    submits: u64,
    every: u64,
}

/// The production router: KV-pressure + queue-depth balancing with
/// prefix-affinity steering (see the module docs for the policy).
#[derive(Clone)]
pub struct KvAwareRouter {
    replicas: Arc<Vec<Submitter>>,
    policy: Arc<Mutex<DispatchPolicy>>,
    pressure: Arc<Mutex<PressureCache>>,
}

impl KvAwareRouter {
    /// Route over `replicas` with the given policy knobs (panics if
    /// `replicas` is empty). `cfg.page_size` must match the backend's;
    /// [`ReplicaSet::kv_router`] fills it in automatically.
    pub fn new(replicas: Vec<Submitter>, cfg: KvRouterConfig) -> KvAwareRouter {
        assert!(!replicas.is_empty(), "router needs at least one replica");
        let n = replicas.len();
        let every = cfg.stats_every.max(1);
        KvAwareRouter {
            replicas: Arc::new(replicas),
            policy: Arc::new(Mutex::new(DispatchPolicy::kv_aware(cfg))),
            pressure: Arc::new(Mutex::new(PressureCache {
                pages: vec![0; n],
                submits: 0,
                every,
            })),
        }
    }

    /// Routing-decision counters so far (also embedded in
    /// [`Router::metrics_report`]).
    pub fn counters(&self) -> RouterCounters {
        lock(&self.policy).counters()
    }

    fn loads(&self) -> Vec<ReplicaLoad> {
        let pages = {
            let mut p = lock(&self.pressure);
            if p.submits % p.every == 0 {
                for (i, s) in self.replicas.iter().enumerate() {
                    if s.health() != Health::Down {
                        if let Ok(stats) = s.engine_stats() {
                            p.pages[i] = stats.kv_pages_used;
                        }
                    }
                }
            }
            p.submits += 1;
            p.pages.clone()
        };
        self.replicas
            .iter()
            .enumerate()
            .map(|(i, s)| ReplicaLoad {
                alive: s.health() != Health::Down,
                in_flight: s.in_flight(),
                kv_pages_used: pages[i],
            })
            .collect()
    }
}

impl Router for KvAwareRouter {
    fn submit(&self, req: Request) -> Result<SessionHandle, SubmitError> {
        dispatch(&self.replicas, &self.policy, &self.loads(), req)
    }

    fn health(&self) -> Health {
        aggregate_health(&self.replicas)
    }

    fn metrics_report(&self) -> Result<String, SubmitError> {
        let c = self.counters();
        let extra = format!(
            " affinity_hits={} affinity_misses={} affinity_reroutes={} affinity_evictions={}",
            c.affinity_hits, c.affinity_misses, c.affinity_reroutes, c.affinity_evictions
        );
        aggregate_report("kv", &extra, &self.replicas)
    }

    fn in_flight(&self) -> usize {
        self.replicas.iter().map(|s| s.in_flight()).sum()
    }

    fn queue_cap(&self) -> usize {
        self.replicas.iter().map(|s| s.queue_cap()).sum()
    }

    fn drain(&self, timeout: Duration) {
        drain_all(&self.replicas, timeout)
    }

    fn replicas(&self) -> usize {
        self.replicas.len()
    }
}

/// Which router policy `--router` selects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterKind {
    /// KV-pressure + prefix-affinity dispatch (the default).
    Kv,
    /// Strict rotation (the routing ablation).
    RoundRobin,
}

impl RouterKind {
    /// Parse a `--router` CLI name (`kv`/`kv-aware`,
    /// `round-robin`/`rr`).
    pub fn parse(s: &str) -> Option<RouterKind> {
        Some(match s {
            "kv" | "kv-aware" | "kvaware" => RouterKind::Kv,
            "round-robin" | "roundrobin" | "rr" => RouterKind::RoundRobin,
            _ => return None,
        })
    }

    /// The stable CLI / metrics name.
    pub fn as_str(&self) -> &'static str {
        match self {
            RouterKind::Kv => "kv",
            RouterKind::RoundRobin => "round-robin",
        }
    }
}

/// Owns N engine-loop replicas: spawning, router construction, and
/// set-wide shutdown. Each replica gets its own scheduler and backend
/// from the factory — per-replica KV allocators stay fully independent,
/// so replicas never contend on an allocator lock and a replica crash
/// cannot corrupt a neighbour's pool.
pub struct ReplicaSet {
    loops: Vec<EngineLoop>,
}

impl ReplicaSet {
    /// Spawn `n` replicas (min 1). `factory(i)` builds replica `i`'s
    /// scheduler-constructor closure, which runs on — and is re-invoked
    /// by — that replica's supervised engine thread, exactly as with
    /// [`EngineLoop::spawn`]. If a later replica fails to spawn, the
    /// earlier ones are shut down before the error returns.
    pub fn spawn<B, G, F>(n: usize, cfg: LoopConfig, mut factory: F) -> Result<ReplicaSet>
    where
        B: Backend + 'static,
        G: FnMut() -> Result<Scheduler<B>> + Send + 'static,
        F: FnMut(usize) -> G,
    {
        let n = n.max(1);
        let mut loops = Vec::with_capacity(n);
        for i in 0..n {
            match EngineLoop::spawn(cfg.clone(), factory(i)) {
                Ok(el) => loops.push(el),
                Err(e) => {
                    for el in loops {
                        el.shutdown();
                    }
                    return Err(e.context(format!("spawning replica {}", i)));
                }
            }
        }
        Ok(ReplicaSet { loops })
    }

    /// Number of replicas in the set.
    pub fn len(&self) -> usize {
        self.loops.len()
    }

    /// Whether the set is empty (never true for a spawned set).
    pub fn is_empty(&self) -> bool {
        self.loops.is_empty()
    }

    /// Cloned submitters, one per replica in index order.
    pub fn submitters(&self) -> Vec<Submitter> {
        self.loops.iter().map(|el| el.submitter()).collect()
    }

    /// Passthrough router over replica 0 (use with N=1).
    pub fn single_router(&self) -> SingleRouter {
        SingleRouter::new(self.loops[0].submitter())
    }

    /// Round-robin ablation router over the whole set.
    pub fn round_robin_router(&self) -> RoundRobinRouter {
        RoundRobinRouter::new(self.submitters())
    }

    /// KV-aware router over the whole set, with the boundary-hash page
    /// size read from replica 0's model config.
    pub fn kv_router(&self) -> Result<KvAwareRouter> {
        let model = self.loops[0]
            .submitter()
            .model_config()
            .map_err(|e| anyhow!("reading replica model config: {}", e))?;
        let cfg = KvRouterConfig { page_size: model.page_size, ..Default::default() };
        Ok(KvAwareRouter::new(self.submitters(), cfg))
    }

    /// Build the serving router for `kind`. One replica always gets the
    /// [`SingleRouter`] passthrough (bit-identical to the pre-router
    /// stack) regardless of `kind`.
    pub fn build_router(&self, kind: RouterKind) -> Result<Arc<dyn Router>> {
        if self.len() == 1 {
            return Ok(Arc::new(self.single_router()));
        }
        Ok(match kind {
            RouterKind::Kv => Arc::new(self.kv_router()?),
            RouterKind::RoundRobin => Arc::new(self.round_robin_router()),
        })
    }

    /// Stop every replica immediately (in-flight sessions cancelled)
    /// and join the engine threads.
    pub fn shutdown(self) {
        for el in self.loops {
            el.shutdown();
        }
    }

    /// Graceful set-wide shutdown: fan one shared drain deadline out to
    /// every replica first (so drains run concurrently, not stacked),
    /// then join each loop as it finishes.
    pub fn shutdown_graceful(self, timeout: Duration) {
        let deadline = Instant::now() + timeout;
        for el in &self.loops {
            el.submitter().drain_until(deadline);
        }
        for el in self.loops {
            el.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::SchedulerConfig;
    use crate::coordinator::sim_backend::SimBackend;

    fn loads(spec: &[(bool, usize, u64)]) -> Vec<ReplicaLoad> {
        spec.iter()
            .map(|&(alive, in_flight, kv)| ReplicaLoad { alive, in_flight, kv_pages_used: kv })
            .collect()
    }

    #[test]
    fn boundary_hashes_are_prefix_consistent_and_stride_aligned() {
        let long: Vec<i32> = (0..17).collect();
        let h_long = prefix_boundary_hashes(&long, 4);
        assert_eq!(h_long.len(), 4, "one hash per completed page");
        let h_short = prefix_boundary_hashes(&long[..8], 4);
        assert_eq!(h_short, h_long[..2], "shared prefix shares hashes");
        let mut other = long.clone();
        other[0] = 999;
        assert_ne!(prefix_boundary_hashes(&other, 4)[0], h_long[0]);
        assert!(prefix_boundary_hashes(&long, 0).is_empty());
        assert!(prefix_boundary_hashes(&long[..3], 4).is_empty());
    }

    #[test]
    fn kv_policy_routes_miss_to_least_loaded_and_hit_to_recorded_replica() {
        let cfg = KvRouterConfig { page_size: 4, ..Default::default() };
        let mut st = KvDispatchState::new(cfg);
        let prompt: Vec<i32> = (0..12).collect();
        // miss: replica 1 has the lower queue depth
        let l = loads(&[(true, 3, 0), (true, 0, 0)]);
        assert_eq!(st.route(&prompt, &l), Some(1));
        st.record(&prompt, 1);
        // hit: same prompt sticks to replica 1 even though 0 drained
        let l = loads(&[(true, 0, 0), (true, 1, 0)]);
        assert_eq!(st.route(&prompt, &l), Some(1));
        // a shorter shared prefix still hits (page-boundary chain)
        assert_eq!(st.route(&prompt[..8], &l), Some(1));
        let c = st.counters();
        assert_eq!((c.affinity_hits, c.affinity_misses), (2, 1));
    }

    #[test]
    fn kv_pressure_steers_misses_but_does_not_repel_affinity() {
        let cfg = KvRouterConfig { page_size: 4, kv_weight: 1.0, ..Default::default() };
        let mut st = KvDispatchState::new(cfg);
        let prompt: Vec<i32> = (0..8).collect();
        // equal queues, replica 0 holds all the KV pages: miss goes to 1
        let l = loads(&[(true, 0, 100), (true, 0, 0)]);
        assert_eq!(st.route(&prompt, &l), Some(1));
        st.record(&prompt, 0);
        // affinity points at the high-pressure replica (its retained
        // pages are exactly why) and must win while queues stay level
        assert_eq!(st.route(&prompt, &l), Some(0));
    }

    #[test]
    fn imbalance_bound_overrides_affinity() {
        let cfg = KvRouterConfig { page_size: 4, imbalance: 2.0, ..Default::default() };
        let mut st = KvDispatchState::new(cfg);
        let prompt: Vec<i32> = (0..8).collect();
        st.record(&prompt, 0);
        // depth 5 vs 1: 5+1 > 2.0*(1+1) → rerouted to least-loaded
        let l = loads(&[(true, 5, 0), (true, 1, 0)]);
        assert_eq!(st.route(&prompt, &l), Some(1));
        assert_eq!(st.counters().affinity_reroutes, 1);
        // depth 2 vs 1: 2+1 <= 2.0*(1+1) → affinity holds
        let l = loads(&[(true, 2, 0), (true, 1, 0)]);
        assert_eq!(st.route(&prompt, &l), Some(0));
    }

    #[test]
    fn dead_replica_affinity_is_a_miss_and_rerecorded() {
        let cfg = KvRouterConfig { page_size: 4, ..Default::default() };
        let mut st = KvDispatchState::new(cfg);
        let prompt: Vec<i32> = (0..8).collect();
        st.record(&prompt, 0);
        let l = loads(&[(false, 0, 0), (true, 2, 0)]);
        assert_eq!(st.route(&prompt, &l), Some(1), "route around the dead replica");
        st.record(&prompt, 1);
        let l = loads(&[(true, 0, 0), (true, 0, 0)]);
        assert_eq!(st.route(&prompt, &l), Some(1), "affinity follows the re-record");
        assert_eq!(st.route(&prompt, &loads(&[(false, 0, 0), (false, 0, 0)])), None);
    }

    #[test]
    fn affinity_map_is_fifo_bounded() {
        let cfg = KvRouterConfig { page_size: 1, affinity_cap: 4, ..Default::default() };
        let mut st = KvDispatchState::new(cfg);
        for i in 0..8i32 {
            st.record(&[i * 1000], i as usize % 2);
        }
        assert_eq!(st.affinity_len(), 4);
        assert_eq!(st.counters().affinity_evictions, 4);
    }

    #[test]
    fn round_robin_rotates_and_skips_dead() {
        let mut p = DispatchPolicy::round_robin();
        let l = loads(&[(true, 0, 0), (false, 0, 0), (true, 9, 0)]);
        let picks: Vec<_> = (0..4).map(|_| p.route(&[], &l).unwrap()).collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
        assert_eq!(p.counters(), RouterCounters::default());
    }

    fn spawn_sim_replica() -> EngineLoop {
        EngineLoop::spawn(LoopConfig { queue_cap: 8, max_engine_restarts: 0 }, || {
            Ok(Scheduler::new(
                SimBackend::tiny(),
                SchedulerConfig { max_batch: 8, admit_below: 8, ..Default::default() },
            ))
        })
        .expect("sim replica spawns")
    }

    #[test]
    fn live_router_routes_around_a_dead_replica_and_degrades() {
        let dead = spawn_sim_replica();
        let live = spawn_sim_replica();
        let dead_sub = dead.submitter();
        let router = KvAwareRouter::new(
            vec![dead.submitter(), live.submitter()],
            KvRouterConfig { page_size: 4, ..Default::default() },
        );
        assert_eq!(Router::health(&router), Health::Ok);
        dead.shutdown();
        assert_eq!(dead_sub.health(), Health::Down);
        assert_eq!(Router::health(&router), Health::Degraded, "one dead replica degrades");
        for i in 0..3 {
            let h = router
                .submit(Request::from_text(0, &format!("route around {} ", i), 4))
                .expect("live replica admits");
            assert_eq!(h.wait().expect("completes").generated_tokens, 4);
        }
        let report = Router::metrics_report(&router).expect("one replica still answers");
        assert!(report.starts_with("router=kv replicas=2 alive=1"), "{}", report);
        assert!(report.contains("replica0 health=down"), "{}", report);
        assert!(report.contains("replica1 "), "{}", report);
        assert!(report.ends_with("health=ok") || report.contains("\nreplica"), "{}", report);
        live.shutdown();
        assert_eq!(Router::health(&router), Health::Down, "all dead is down");
        assert!(Router::metrics_report(&router).is_err());
        assert!(matches!(
            router.submit(Request::from_text(0, "too late ", 2)),
            Err(SubmitError::Closed)
        ));
    }

    #[test]
    fn replica_set_spawns_n_and_aggregates_capacity() {
        let set = ReplicaSet::spawn(
            3,
            LoopConfig { queue_cap: 4, max_engine_restarts: 0 },
            |_i| {
                || {
                    Ok(Scheduler::new(
                        SimBackend::tiny(),
                        SchedulerConfig { max_batch: 8, admit_below: 8, ..Default::default() },
                    ))
                }
            },
        )
        .expect("set spawns");
        assert_eq!(set.len(), 3);
        let router = set.build_router(RouterKind::Kv).expect("router builds");
        assert_eq!(router.replicas(), 3);
        assert_eq!(router.queue_cap(), 12, "aggregate admission capacity");
        let c = set.submitters()[0].model_config().expect("model config answers");
        assert_eq!(c.page_size, crate::coordinator::sim_backend::sim_config().page_size);
        let h = router.submit(Request::from_text(0, "spawned set serves ", 3)).unwrap();
        assert_eq!(h.wait().unwrap().generated_tokens, 3);
        set.shutdown_graceful(Duration::from_secs(5));
    }
}
