//! Continuous-batching scheduler: the pure policy/state core of the
//! serving stack. It owns the request queue and running set, admits
//! queued requests via prefill (bursting when the engine is idle),
//! interleaves batched decode steps, and reports everything that
//! happened in a tick as [`StepEvent`]s — per-token emission included —
//! so callers (the [`EngineLoop`](crate::coordinator::engine_loop), the
//! load-test driver, tests) can route tokens to sessions as they are
//! sampled instead of waiting for completions.
//!
//! The scheduler never blocks and never touches the network; threading
//! and session channels live in `coordinator::engine_loop`. Completions
//! are handed out exactly once via [`Scheduler::take_completion`] (or
//! dropped after a bounded backlog), so nothing accumulates for the
//! lifetime of the server.

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::engine::{
    sample_token, Backend, Engine, PrefillDone, SampleParams, Sequence,
};
use crate::coordinator::metrics::{Metrics, RequestTiming};
use crate::coordinator::tokenizer;
use crate::kvcache::{AdmitDecision, KvPoolStats};

/// A queued generation request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Caller-chosen id; must be unique among in-flight requests (the
    /// `Submitter` assigns fresh ids automatically).
    pub id: u64,
    /// Prompt tokens.
    pub prompt: Vec<i32>,
    /// Generation budget.
    pub max_new_tokens: usize,
    /// Sampling parameters.
    pub sample: SampleParams,
    /// Stop strings: generation finishes when the decoded output
    /// contains any of them; the completion text is truncated at the
    /// first match.
    pub stop: Vec<String>,
}

impl Request {
    /// Greedy request over byte-tokenized `text` with no stop strings.
    pub fn from_text(id: u64, text: &str, max_new: usize) -> Request {
        Request {
            id,
            prompt: tokenizer::encode(text),
            max_new_tokens: max_new,
            sample: SampleParams::greedy(),
            stop: Vec::new(),
        }
    }
}

/// Why a request stopped generating.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// hit its `max_new_tokens` budget.
    Length,
    /// sampled the EOS token.
    Eos,
    /// matched a stop string.
    Stop,
    /// cancelled by the client (disconnect or explicit cancel).
    Cancelled,
}

impl FinishReason {
    /// Lowercase wire form (HTTP responses, logs).
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Eos => "eos",
            FinishReason::Stop => "stop",
            FinishReason::Cancelled => "cancelled",
        }
    }
}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct Completion {
    /// The request's id.
    pub id: u64,
    /// Generated tokens (prompt excluded).
    pub tokens: Vec<i32>,
    /// Decoded output text (stop-truncated if a stop string matched).
    pub text: String,
    /// Prompt length in tokens.
    pub prompt_tokens: usize,
    /// Number of generated tokens.
    pub generated_tokens: usize,
    /// Why generation stopped.
    pub finish_reason: FinishReason,
}

/// What happened during one [`Scheduler::tick`], in order. Token events
/// are emitted the tick the token is sampled (prefill's first token
/// included), which is what makes streaming and per-token latency
/// metrics possible.
#[derive(Debug, Clone)]
pub enum StepEvent {
    /// One newly sampled token for request `id`. `text` is the decoded
    /// delta released so far — empty for special tokens and while a
    /// suffix is held back pending a stop-string decision; concatenated
    /// deltas always equal the completion text.
    Token { id: u64, index: usize, token: i32, text: String },
    /// Request `id` finished; its completion is waiting in
    /// [`Scheduler::take_completion`].
    Finished { id: u64 },
    /// Admission failed for request `id` (e.g. the prompt exceeds the
    /// compiled prefill buckets). Per-request: other sequences continue.
    Failed { id: u64, error: String },
}

struct Queued {
    req: Request,
    arrived: Instant,
}

/// Request-side metadata held while its sequence prefills inside the
/// backend (possibly asynchronously, overlapped with decode).
struct Prefilling {
    timing: RequestTiming,
    stop: Vec<String>,
    prompt_len: usize,
}

struct Running {
    seq: Sequence,
    timing: RequestTiming,
    /// Decoded output accumulated per token (stop-string window and the
    /// completion text).
    text: String,
    stop: Vec<String>,
    /// Output tokens already reported as [`StepEvent::Token`].
    emitted: usize,
    /// Bytes of `text` already released in `Token` events. Trails
    /// `text.len()` by the longest suffix that could still become a
    /// stop-string match, so streamed deltas concatenate exactly to the
    /// (possibly stop-truncated) completion text.
    sent: usize,
    stop_hit: bool,
}

/// Scheduler policy knobs.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// max sequences decoded together (bounded by compiled buckets).
    pub max_batch: usize,
    /// admit new prefills only when the running set is below this.
    pub admit_below: usize,
    /// max unclaimed completions retained for `take_completion` before
    /// the oldest are dropped (leak guard for callers that never claim).
    pub completion_backlog: usize,
    /// When the decode batch reaches this many sequences, split it into
    /// up to `max_lanes` microbatch lanes dispatched together
    /// (`Backend::decode_step_lanes`), so a backend with an executor
    /// pool keeps several artifact streams in flight. `0` disables
    /// splitting. Token outputs are unchanged: the lane set appends one
    /// token to every sequence just like a joint step, and pure-policy
    /// backends run the lanes back to back. Cost note: on the pooled
    /// real engine, lane mode runs weight-bearing artifacts on the
    /// pool's designated weight workers (see
    /// `FreeKvParams::weight_workers`).
    pub microbatch_min: usize,
    /// Most microbatch lanes a split decode batch is divided into. The
    /// real engine re-plans the partition bucket-aware (merging lanes
    /// whose split would not shrink the compiled bucket), so this is an
    /// upper bound, not a promise. `< 2` disables splitting.
    pub max_lanes: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 4,
            admit_below: 4,
            completion_backlog: 256,
            microbatch_min: 0,
            max_lanes: 2,
        }
    }
}

/// Continuous-batching scheduler: admits queued requests against the
/// backend's KV-pool capacity, drives prefill and batched decode via
/// [`Scheduler::tick`], and emits per-token [`StepEvent`]s.
pub struct Scheduler<B: Backend = Engine> {
    /// The backend (real engine or sim) this scheduler drives.
    pub engine: B,
    /// Policy knobs.
    pub cfg: SchedulerConfig,
    queue: VecDeque<Queued>,
    running: Vec<Running>,
    /// Requests whose sequences are prefilling inside the backend.
    prefilling: HashMap<u64, Prefilling>,
    /// Serving metrics (TTFT/ITL/TPOT histograms + counters).
    pub metrics: Metrics,
    finished: HashMap<u64, Completion>,
    finished_order: VecDeque<u64>,
}

impl<B: Backend> Scheduler<B> {
    /// Scheduler over a backend with the given policy knobs.
    pub fn new(engine: B, cfg: SchedulerConfig) -> Scheduler<B> {
        Scheduler {
            engine,
            cfg,
            queue: VecDeque::new(),
            running: Vec::new(),
            prefilling: HashMap::new(),
            metrics: Metrics::new(),
            finished: HashMap::new(),
            finished_order: VecDeque::new(),
        }
    }

    /// Enqueue a request, stamping arrival now.
    pub fn submit(&mut self, req: Request) {
        self.submit_arrived(req, Instant::now());
    }

    /// Submit with an externally measured arrival timestamp — the
    /// engine loop stamps arrival at the `Submitter` call site so TTFT
    /// includes the command-channel wait, not just queue time.
    pub fn submit_arrived(&mut self, req: Request, arrived: Instant) {
        self.metrics.on_arrival(req.prompt.len());
        self.queue.push_back(Queued { req, arrived });
    }

    /// Requests not yet finished (queued + prefilling + running).
    pub fn pending(&self) -> usize {
        self.queue.len() + self.prefilling.len() + self.running.len()
    }

    /// Requests waiting in the admission queue.
    pub fn queued_len(&self) -> usize {
        self.queue.len()
    }

    /// Requests currently decoding.
    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Requests whose prefill is in flight inside the backend.
    pub fn prefilling_len(&self) -> usize {
        self.prefilling.len()
    }

    /// Ids of every queued, prefilling, or running request.
    pub fn active_ids(&self) -> Vec<u64> {
        self.queue
            .iter()
            .map(|q| q.req.id)
            .chain(self.prefilling.keys().copied())
            .chain(self.running.iter().map(|r| r.seq.id))
            .collect()
    }

    /// Bytes of KV state (GPU-resident + CPU pool) held by running
    /// sequences — drops back to zero when they finish or are cancelled.
    /// (Sequences mid-prefill are owned by the backend and not counted;
    /// shared pool pages count once per referencing request here — the
    /// process-wide figure is [`Scheduler::kv_pool_stats`].)
    pub fn running_kv_bytes(&self) -> usize {
        self.running.iter().map(|r| r.seq.kv.gpu_bytes() + r.seq.kv.cpu_bytes()).sum()
    }

    /// Live gauges of the backend's shared KV pool (pages, prefix hits,
    /// allocator-charged bytes — shared pages counted once).
    pub fn kv_pool_stats(&self) -> KvPoolStats {
        self.engine.kv_stats()
    }

    /// One scheduling iteration: admission (prefill handed to the
    /// backend, possibly asynchronous), harvest of completed prefills,
    /// one batched decode step (split into microbatch lanes when
    /// configured), then retirement of finished sequences. Returns the
    /// tick's events in emission order. Decode errors are engine-global
    /// and propagate; admission/prefill errors are per-request `Failed`
    /// events.
    pub fn tick(&mut self) -> Result<Vec<StepEvent>> {
        let mut events = Vec::new();
        self.admit(&mut events);
        self.harvest(&mut events);
        if self.running.is_empty() && !self.prefilling.is_empty() {
            // Nothing to decode yet: block for the first prefill so the
            // tick always makes progress.
            let done = self.engine.prefill_wait();
            if done.is_empty() {
                // The backend lost track of prefills it accepted — fail
                // them rather than spinning forever.
                let ids: Vec<u64> = self.prefilling.keys().copied().collect();
                for id in ids {
                    self.prefilling.remove(&id);
                    self.engine.kv_release(id);
                    self.metrics.on_failed();
                    events.push(StepEvent::Failed {
                        id,
                        error: "backend dropped an in-flight prefill".into(),
                    });
                }
            } else {
                for d in done {
                    self.finish_harvested(d, &mut events);
                }
            }
        }
        self.decode(&mut events)?;
        self.harvest(&mut events);
        self.retire(&mut events);
        Ok(events)
    }

    /// Admission: prefill-priority and capacity-aware. One prefill per
    /// tick while decode is in flight (keeps running sequences' ITL
    /// steady), bursting up to `admit_below` when the engine is idle so
    /// a queued backlog doesn't pay one decode step of TTFT per
    /// request. Prefilling sequences occupy admission slots like
    /// running ones. Before a request starts, its worst-case KV page
    /// footprint is charged against the backend's shared pool
    /// ([`Backend::kv_admit`]): when the pool cannot cover it the
    /// request stays queued (FIFO — no head-of-line skipping) and
    /// retries once a finish/cancel frees pages; a footprint larger
    /// than the whole pool fails that request alone.
    fn admit(&mut self, events: &mut Vec<StepEvent>) {
        let occupied = self.running.len() + self.prefilling.len();
        let burst = if occupied == 0 { self.cfg.admit_below } else { 1 };
        let mut admitted = 0;
        while admitted < burst
            && self.running.len() + self.prefilling.len() < self.cfg.admit_below
        {
            let Some(front) = self.queue.front() else { break };
            let id = front.req.id;
            let prompt_len = front.req.prompt.len();
            // same clamp as begin_prefill, so the charged footprint
            // matches what the request can actually decode
            let budget = self.engine.model().max_context.saturating_sub(prompt_len).max(1);
            let max_new = front.req.max_new_tokens.min(budget);
            match self.engine.kv_admit(id, prompt_len, max_new) {
                AdmitDecision::Admit => {
                    let q = self.queue.pop_front().expect("front exists");
                    admitted += 1;
                    self.begin_prefill(q, events);
                }
                AdmitDecision::Wait => break,
                AdmitDecision::Never => {
                    let q = self.queue.pop_front().expect("front exists");
                    self.metrics.on_failed();
                    events.push(StepEvent::Failed {
                        id: q.req.id,
                        error: format!(
                            "request KV footprint ({} prompt + {} new tokens) exceeds the pool",
                            prompt_len, max_new
                        ),
                    });
                }
            }
        }
    }

    /// Build the sequence and hand it to the backend. A synchronous
    /// backend completes right here; an asynchronous one parks the
    /// request in `prefilling` until `harvest` claims it.
    fn begin_prefill(&mut self, q: Queued, events: &mut Vec<StepEvent>) {
        let id = q.req.id;
        let prompt_len = q.req.prompt.len();
        let mut timing = RequestTiming::new(prompt_len);
        timing.arrived = q.arrived; // TTFT includes queueing delay
        // Defensive cap: one hostile max_tokens must not decode past the
        // model context and poison the shared engine's compiled buckets.
        let budget = self.engine.model().max_context.saturating_sub(prompt_len).max(1);
        let max_new = q.req.max_new_tokens.min(budget);
        let mut seq = self.engine.new_sequence(id, q.req.prompt, max_new, q.req.sample.clone());
        seq.eos = Some(tokenizer::EOS);
        let meta = Prefilling { timing, stop: q.req.stop, prompt_len };
        match self.engine.prefill_begin(seq) {
            Some(done) => self.finish_prefill(done, meta, events),
            None => {
                self.prefilling.insert(id, meta);
            }
        }
    }

    /// Claim completed asynchronous prefills from the backend.
    fn harvest(&mut self, events: &mut Vec<StepEvent>) {
        for done in self.engine.prefill_poll() {
            self.finish_harvested(done, events);
        }
    }

    fn finish_harvested(&mut self, done: PrefillDone, events: &mut Vec<StepEvent>) {
        let Some(meta) = self.prefilling.remove(&done.seq.id) else {
            // cancelled while in flight; the sequence (and its KV) drops
            return;
        };
        self.finish_prefill(done, meta, events);
    }

    /// Sample the first token of a completed prefill and move the
    /// request into the running set (or report its failure).
    fn finish_prefill(&mut self, done: PrefillDone, meta: Prefilling, events: &mut Vec<StepEvent>) {
        let PrefillDone { mut seq, result } = done;
        let id = seq.id;
        let mut timing = meta.timing;
        match result {
            Ok(lg) => {
                let params = seq.sample.clone();
                let tok = sample_token(&lg, &params, &mut seq.rng);
                seq.tokens.push(tok);
                if Some(tok) == seq.eos {
                    seq.finished = true;
                }
                timing.prefill_done = Some(Instant::now());
                let mut r = Running {
                    seq,
                    timing,
                    text: String::new(),
                    stop: meta.stop,
                    emitted: 0,
                    sent: 0,
                    stop_hit: false,
                };
                Self::emit_new_tokens(&mut self.metrics, &mut r, events);
                self.running.push(r);
            }
            Err(e) => {
                self.engine.kv_release(id);
                self.metrics.on_failed();
                events.push(StepEvent::Failed { id, error: format!("{e:#}") });
            }
        }
    }

    fn decode(&mut self, events: &mut Vec<StepEvent>) -> Result<()> {
        if self.running.is_empty() {
            return Ok(());
        }
        let limit = self.cfg.max_batch.min(self.running.len());
        {
            // Finished lanes (EOS at prefill, stop hit) must not decode
            // another token — the engine contract skips them here.
            let batch: Vec<&mut Sequence> = self.running[..limit]
                .iter_mut()
                .map(|r| &mut r.seq)
                .filter(|s| !s.done())
                .collect();
            if batch.is_empty() {
                return Ok(());
            }
            // Large enough running set: split into up to `max_lanes`
            // microbatch lanes so the backend can keep several in
            // flight concurrently (the real engine re-plans the
            // partition bucket-aware).
            let split = self.cfg.microbatch_min > 0
                && self.cfg.max_lanes >= 2
                && batch.len() >= self.cfg.microbatch_min
                && batch.len() >= 2;
            let step_result = if split {
                let widths =
                    crate::util::balanced_widths(batch.len(), self.cfg.max_lanes.min(batch.len()));
                let mut lanes: Vec<Vec<&mut Sequence>> = Vec::with_capacity(widths.len());
                let mut it = batch.into_iter();
                for w in widths {
                    lanes.push(it.by_ref().take(w).collect());
                }
                self.engine.decode_step_lanes(&mut lanes)
            } else {
                let mut batch = batch;
                self.engine.decode_step(&mut batch)
            };
            if let Err(e) = step_result {
                // A failed lane set may still have advanced its other
                // lanes (the containment contract): fold those tokens
                // into the per-request accumulators and metrics before
                // the error propagates, so completions taken during the
                // subsequent teardown (cancel on shutdown) carry every
                // token that was actually generated and the token
                // counters stay truthful.
                for r in self.running[..limit].iter_mut() {
                    Self::emit_new_tokens(&mut self.metrics, r, events);
                }
                return Err(e);
            }
        }
        for r in self.running[..limit].iter_mut() {
            Self::emit_new_tokens(&mut self.metrics, r, events);
        }
        Ok(())
    }

    /// Report every not-yet-emitted output token of `r`: record per-token
    /// metrics, append to the text accumulator, check stop strings, and
    /// push one `Token` event per token. A suffix that could still grow
    /// into a stop match is held back, so the concatenated event deltas
    /// always equal the final (stop-truncated) completion text; the held
    /// text flushes as soon as the match becomes impossible or the
    /// sequence finishes.
    fn emit_new_tokens(metrics: &mut Metrics, r: &mut Running, events: &mut Vec<StepEvent>) {
        while r.emitted < r.seq.generated().len() {
            let idx = r.emitted;
            let tok = r.seq.tokens[r.seq.prompt_len + idx];
            r.emitted += 1;
            metrics.on_token(&mut r.timing);
            let delta = tokenizer::decode(&[tok]);
            let old_len = r.text.len();
            r.text.push_str(&delta);
            if !r.stop_hit {
                // A new match must end inside the delta, so only the
                // tail window can contain one (keeps this O(output)).
                let max_stop = r.stop.iter().map(|s| s.len()).max().unwrap_or(0);
                let scan_from = old_len.saturating_sub(max_stop.saturating_sub(1));
                if let Some(pos) = find_stop(&r.text, &r.stop, scan_from) {
                    r.stop_hit = true;
                    r.seq.finished = true;
                    r.text.truncate(pos);
                }
            }
            let boundary = if r.stop_hit || r.seq.done() {
                r.text.len()
            } else {
                r.text.len() - stop_holdback(&r.text, &r.stop)
            };
            let emit = if boundary > r.sent {
                let s = r.text[r.sent..boundary].to_string();
                r.sent = boundary;
                s
            } else {
                String::new()
            };
            events.push(StepEvent::Token { id: r.seq.id, index: idx, token: tok, text: emit });
        }
    }

    fn retire(&mut self, events: &mut Vec<StepEvent>) {
        if self.running.iter().all(|r| !r.seq.done()) {
            return;
        }
        let mut still = Vec::with_capacity(self.running.len());
        for mut r in self.running.drain(..) {
            if r.seq.done() {
                r.timing.finished = Some(Instant::now());
                self.metrics.on_complete(&r.timing);
                let reason = if r.stop_hit {
                    FinishReason::Stop
                } else if r.seq.finished {
                    FinishReason::Eos
                } else {
                    FinishReason::Length
                };
                let id = r.seq.id;
                let c = Completion {
                    id,
                    text: r.text,
                    tokens: r.seq.tokens.clone(),
                    prompt_tokens: r.seq.prompt_len,
                    generated_tokens: r.seq.generated().len(),
                    finish_reason: reason,
                };
                Self::store_completion(&mut self.finished, &mut self.finished_order, &self.cfg, c);
                // the sequence (and its pool pages) drops here; give the
                // admission reservation back so queued requests resume
                self.engine.kv_release(id);
                events.push(StepEvent::Finished { id });
            } else {
                still.push(r);
            }
        }
        self.running = still;
    }

    fn store_completion(
        finished: &mut HashMap<u64, Completion>,
        order: &mut VecDeque<u64>,
        cfg: &SchedulerConfig,
        c: Completion,
    ) {
        let id = c.id;
        if finished.insert(id, c).is_none() {
            order.push_back(id);
        }
        while order.len() > cfg.completion_backlog.max(1) {
            if let Some(old) = order.pop_front() {
                finished.remove(&old);
            }
        }
    }

    /// Claim a finished request's completion. Each completion can be
    /// taken exactly once; unclaimed ones are dropped after
    /// `completion_backlog` newer completions.
    pub fn take_completion(&mut self, id: u64) -> Option<Completion> {
        let c = self.finished.remove(&id)?;
        if let Some(i) = self.finished_order.iter().position(|&x| x == id) {
            self.finished_order.remove(i);
        }
        Some(c)
    }

    /// Cancel a queued or running request mid-flight: retires the
    /// sequence through the engine (reclaiming any in-flight transfer
    /// state) and releases its KV slots and CPU pool pages by dropping
    /// the sequence. A `Cancelled` completion with the tokens generated
    /// so far is left for `take_completion`. Returns false if `id` is
    /// not in flight.
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(i) = self.queue.iter().position(|q| q.req.id == id) {
            let q = self.queue.remove(i).expect("index from position");
            self.metrics.on_cancelled();
            let c = Completion {
                id,
                prompt_tokens: q.req.prompt.len(),
                tokens: q.req.prompt,
                text: String::new(),
                generated_tokens: 0,
                finish_reason: FinishReason::Cancelled,
            };
            Self::store_completion(&mut self.finished, &mut self.finished_order, &self.cfg, c);
            return true;
        }
        if let Some(meta) = self.prefilling.remove(&id) {
            // Reclaim the sequence from the backend's prefill machinery
            // so its KV drops here; any chunk still executing completes
            // on a worker and is discarded.
            let seq = self.engine.prefill_cancel(id);
            self.engine.kv_release(id);
            self.metrics.on_cancelled();
            let (tokens, prompt_tokens) = match seq {
                Some(s) => (s.tokens.clone(), s.prompt_len),
                None => (Vec::new(), meta.prompt_len),
            };
            let c = Completion {
                id,
                tokens,
                text: String::new(),
                prompt_tokens,
                generated_tokens: 0,
                finish_reason: FinishReason::Cancelled,
            };
            Self::store_completion(&mut self.finished, &mut self.finished_order, &self.cfg, c);
            return true;
        }
        if let Some(i) = self.running.iter().position(|r| r.seq.id == id) {
            let mut r = self.running.remove(i);
            self.engine.retire_sequence(&mut r.seq);
            self.engine.kv_release(id);
            self.metrics.on_cancelled();
            let c = Completion {
                id,
                text: r.text,
                tokens: r.seq.tokens.clone(),
                prompt_tokens: r.seq.prompt_len,
                generated_tokens: r.seq.generated().len(),
                finish_reason: FinishReason::Cancelled,
            };
            Self::store_completion(&mut self.finished, &mut self.finished_order, &self.cfg, c);
            return true;
        }
        false
    }

    /// Fail a request mid-flight (engine-fault teardown): the same
    /// resource reclamation as [`Scheduler::cancel`] — sequence retired,
    /// KV pages and reservation released — but the request counts as
    /// `failed`, not `cancelled`, and leaves no completion behind (the
    /// caller surfaces the fault as the session's terminal error event).
    /// Returns false if `id` is not in flight.
    pub fn abort(&mut self, id: u64) -> bool {
        if !self.cancel(id) {
            return false;
        }
        let _ = self.take_completion(id);
        self.metrics.cancelled -= 1;
        self.metrics.on_failed();
        true
    }

    /// Run until every queued request completes. Completions stay
    /// claimable via [`Scheduler::take_completion`] (bounded backlog).
    pub fn drain(&mut self) -> Result<()> {
        while self.pending() > 0 {
            self.tick()?;
        }
        Ok(())
    }
}

/// Earliest match position of any stop string in `text`, scanning only
/// from `scan_from` (clamped back to a char boundary).
fn find_stop(text: &str, stops: &[String], scan_from: usize) -> Option<usize> {
    let mut from = scan_from.min(text.len());
    while from > 0 && !text.is_char_boundary(from) {
        from -= 1;
    }
    stops
        .iter()
        .filter(|s| !s.is_empty())
        .filter_map(|s| text[from..].find(s.as_str()).map(|p| from + p))
        .min()
}

/// Longest proper prefix of any stop string that `text` ends with —
/// the byte count a streaming emitter must hold back because the next
/// token may complete the stop.
fn stop_holdback(text: &str, stops: &[String]) -> usize {
    let mut hold = 0;
    for s in stops {
        for (k, _) in s.char_indices().skip(1) {
            if k > hold && text.ends_with(&s[..k]) {
                hold = k;
            }
        }
    }
    hold
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sim_backend::{sim_next_token, SimBackend};

    fn sim_sched(cfg: SchedulerConfig) -> Scheduler<SimBackend> {
        Scheduler::new(SimBackend::tiny(), cfg)
    }

    fn count_tokens(events: &[StepEvent]) -> usize {
        events.iter().filter(|e| matches!(e, StepEvent::Token { .. })).count()
    }

    #[test]
    fn events_and_completions_per_request() {
        let mut s = sim_sched(SchedulerConfig::default());
        s.submit(Request::from_text(1, "alpha ", 5));
        s.submit(Request::from_text(2, "beta ", 3));
        let mut tokens = 0;
        let mut done = Vec::new();
        while s.pending() > 0 {
            for ev in s.tick().unwrap() {
                match ev {
                    StepEvent::Token { .. } => tokens += 1,
                    StepEvent::Finished { id } => done.push(id),
                    StepEvent::Failed { id, error } => panic!("req {} failed: {}", id, error),
                }
            }
        }
        assert_eq!(tokens, 5 + 3);
        done.sort_unstable();
        assert_eq!(done, vec![1, 2]);
        let c1 = s.take_completion(1).unwrap();
        assert_eq!(c1.generated_tokens, 5);
        assert_eq!(c1.finish_reason, FinishReason::Length);
        assert_eq!(c1.text.len(), 5, "printable sim tokens decode 1:1");
        assert!(s.take_completion(1).is_none(), "completions are take-once");
        assert!(s.take_completion(2).is_some());
        assert_eq!(s.metrics.completed, 2);
        assert_eq!(s.metrics.tokens_out, 8);
        assert_eq!(s.metrics.ttft.count(), 2);
        assert_eq!(s.metrics.itl.count(), 8 - 2);
    }

    #[test]
    fn idle_burst_admission_vs_one_per_tick() {
        let cfg = SchedulerConfig { max_batch: 4, admit_below: 4, ..Default::default() };
        let mut s = sim_sched(cfg);
        for i in 1..=2 {
            s.submit(Request::from_text(i, "queued burst ", 50));
        }
        // running set empty + deep queue: one tick admits both
        let ev = s.tick().unwrap();
        assert_eq!(s.running_len(), 2, "idle burst admits up to admit_below");
        assert!(count_tokens(&ev) >= 2, "each admitted request got its first token");
        // decode in flight: admission throttles back to one per tick
        s.submit(Request::from_text(3, "late ", 50));
        s.submit(Request::from_text(4, "later ", 50));
        s.tick().unwrap();
        assert_eq!(s.running_len(), 3, "one admission per tick while decoding");
        s.tick().unwrap();
        assert_eq!(s.running_len(), 4);
    }

    #[test]
    fn microbatch_split_preserves_outputs_and_halves_lanes() {
        // Same four requests with and without microbatching: identical
        // completions (the pair path is a pure scheduling change), but
        // the split run decodes two half-width batches per tick.
        let run = |microbatch_min: usize| {
            let cfg = SchedulerConfig {
                max_batch: 4,
                admit_below: 4,
                microbatch_min,
                ..Default::default()
            };
            let mut s = sim_sched(cfg);
            for i in 1..=4u64 {
                s.submit(Request::from_text(i, &format!("microbatch req {} ", i), 12));
            }
            s.drain().unwrap();
            let texts: Vec<String> =
                (1..=4u64).map(|i| s.take_completion(i).unwrap().text).collect();
            let st = s.engine.stats().clone();
            (texts, st.max_batch_lanes, st.steps)
        };
        let (joint_texts, joint_lanes, joint_steps) = run(0);
        let (split_texts, split_lanes, split_steps) = run(4);
        assert_eq!(joint_texts, split_texts, "microbatching changed outputs");
        assert_eq!(joint_lanes, 4, "joint run decodes all four lanes together");
        assert_eq!(split_lanes, 2, "split run decodes two microbatches of two");
        assert!(
            split_steps > joint_steps,
            "pair dispatch counts both microbatch invocations ({} vs {})",
            split_steps,
            joint_steps
        );
    }

    #[test]
    fn cancel_running_frees_kv_and_leaves_cancelled_completion() {
        let mut s = sim_sched(SchedulerConfig::default());
        s.submit(Request::from_text(7, "cancel me ", 100));
        s.submit(Request::from_text(8, "keep me ", 10));
        for _ in 0..3 {
            s.tick().unwrap();
        }
        assert_eq!(s.running_len(), 2);
        let bytes_two = s.running_kv_bytes();
        assert!(bytes_two > 0);
        assert!(s.cancel(7));
        assert_eq!(s.running_len(), 1);
        assert!(s.running_kv_bytes() < bytes_two, "cancelled sequence's KV released");
        let c = s.take_completion(7).unwrap();
        assert_eq!(c.finish_reason, FinishReason::Cancelled);
        assert!(c.generated_tokens > 0, "tokens generated before cancel are kept");
        assert!(!s.cancel(7), "already gone");
        assert!(!s.cancel(999));
        s.drain().unwrap();
        assert_eq!(s.running_kv_bytes(), 0);
        assert_eq!(s.take_completion(8).unwrap().finish_reason, FinishReason::Length);
        assert_eq!(s.metrics.cancelled, 1);
    }

    #[test]
    fn cancel_queued_request() {
        let cfg = SchedulerConfig { admit_below: 1, ..Default::default() };
        let mut s = sim_sched(cfg);
        s.submit(Request::from_text(1, "first ", 4));
        s.submit(Request::from_text(2, "second ", 4));
        s.tick().unwrap();
        assert_eq!(s.queued_len(), 1);
        assert!(s.cancel(2));
        assert_eq!(s.queued_len(), 0);
        let c = s.take_completion(2).unwrap();
        assert_eq!(c.finish_reason, FinishReason::Cancelled);
        assert_eq!(c.generated_tokens, 0);
        s.drain().unwrap();
        assert!(s.take_completion(1).is_some());
    }

    #[test]
    fn stop_string_truncates_text_and_stream_agrees() {
        // Predict the sim stream from the prompt's last token, pick a
        // substring as the stop, and check truncation + streamed text.
        let prompt = "stop test ";
        let mut last = *tokenizer::encode(prompt).last().unwrap();
        let mut expected = String::new();
        for _ in 0..20 {
            last = sim_next_token(last);
            expected.push(last as u8 as char);
        }
        let stop = expected[6..9].to_string();
        let cut = expected.find(&stop).unwrap();

        let mut s = sim_sched(SchedulerConfig::default());
        let mut req = Request::from_text(1, prompt, 20);
        req.stop = vec![stop];
        s.submit(req);
        let mut streamed = String::new();
        while s.pending() > 0 {
            for ev in s.tick().unwrap() {
                if let StepEvent::Token { text, .. } = ev {
                    streamed.push_str(&text);
                }
            }
        }
        let c = s.take_completion(1).unwrap();
        assert_eq!(c.finish_reason, FinishReason::Stop);
        assert_eq!(c.text, expected[..cut].to_string());
        assert_eq!(streamed, c.text, "streamed deltas equal completion text");
        assert!(c.generated_tokens < 20, "stopped before the length budget");
    }

    #[test]
    fn holdback_releases_when_the_stop_match_fails() {
        // A stop whose first char appears in the stream (but never the
        // full stop) must not eat output: held-back bytes are released
        // once the match becomes impossible, and everything flushes by
        // the time the request finishes.
        let prompt = "holdback ";
        let mut last = *tokenizer::encode(prompt).last().unwrap();
        let mut expected = String::new();
        for _ in 0..12 {
            last = sim_next_token(last);
            expected.push(last as u8 as char);
        }
        // first char of the stream + a char that never follows it
        let first = expected.chars().next().unwrap();
        let never = (32..127u8)
            .map(|b| b as char)
            .find(|&c| !expected.contains(&format!("{}{}", first, c)))
            .expect("some 2-gram is absent from 12 chars");
        let stop = format!("{}{}", first, never);
        assert!(!expected.contains(&stop));

        let mut s = sim_sched(SchedulerConfig::default());
        let mut req = Request::from_text(1, prompt, 12);
        req.stop = vec![stop];
        s.submit(req);
        let mut streamed = String::new();
        while s.pending() > 0 {
            for ev in s.tick().unwrap() {
                if let StepEvent::Token { text, .. } = ev {
                    streamed.push_str(&text);
                }
            }
        }
        let c = s.take_completion(1).unwrap();
        assert_eq!(c.finish_reason, FinishReason::Length);
        assert_eq!(c.text, expected);
        assert_eq!(streamed, expected, "held-back bytes must all be released");
    }

    #[test]
    fn max_tokens_is_clamped_to_model_context() {
        let mut s = sim_sched(SchedulerConfig::default());
        let ctx = s.engine.model().max_context;
        let mut req = Request::from_text(1, "clamp ", usize::MAX);
        let prompt_len = req.prompt.len();
        req.max_new_tokens = usize::MAX;
        s.submit(req);
        s.drain().unwrap();
        let c = s.take_completion(1).unwrap();
        assert_eq!(c.generated_tokens, ctx - prompt_len, "decode stops at the context edge");
        assert_eq!(c.finish_reason, FinishReason::Length);
    }

    #[test]
    fn oversize_prompt_fails_that_request_only() {
        let mut s = sim_sched(SchedulerConfig::default());
        s.engine.max_prompt = 16;
        s.submit(Request {
            id: 1,
            prompt: vec![65; 64],
            max_new_tokens: 4,
            sample: SampleParams::greedy(),
            stop: vec![],
        });
        s.submit(Request::from_text(2, "fine ", 4));
        let mut failed = None;
        while s.pending() > 0 {
            for ev in s.tick().unwrap() {
                if let StepEvent::Failed { id, error } = ev {
                    failed = Some((id, error));
                }
            }
        }
        let (id, error) = failed.expect("oversize prompt reported");
        assert_eq!(id, 1);
        assert!(error.contains("exceeds"), "{}", error);
        assert!(s.take_completion(2).is_some());
        assert!(s.take_completion(1).is_none());
        assert_eq!(s.metrics.failed, 1);
    }

    #[test]
    fn four_lane_split_preserves_outputs() {
        // The same eight requests decoded jointly and as four 2-wide
        // lanes must complete with identical texts — the lane set is a
        // pure scheduling change for any backend.
        let run = |max_lanes: usize, microbatch_min: usize| {
            let cfg = SchedulerConfig {
                max_batch: 8,
                admit_below: 8,
                microbatch_min,
                max_lanes,
                ..Default::default()
            };
            let mut s = sim_sched(cfg);
            for i in 1..=8u64 {
                s.submit(Request::from_text(i, &format!("lane req {} ", i), 10));
            }
            s.drain().unwrap();
            let texts: Vec<String> =
                (1..=8u64).map(|i| s.take_completion(i).unwrap().text).collect();
            (texts, s.engine.stats().max_batch_lanes)
        };
        let (joint, joint_lanes) = run(2, 0);
        let (split, split_lanes) = run(4, 4);
        assert_eq!(joint, split, "4-lane split changed outputs");
        assert_eq!(joint_lanes, 8, "joint run decodes all eight lanes together");
        assert_eq!(split_lanes, 2, "8 sequences over 4 lanes decode 2-wide");
    }

    #[test]
    fn async_prefill_overlaps_decode() {
        let mut s = sim_sched(SchedulerConfig::default());
        s.submit(Request::from_text(1, "first ", 24));
        s.tick().unwrap();
        assert_eq!(s.running_len(), 1);
        // subsequent prefills take several poll rounds to complete
        s.engine.prefill_ticks = 4;
        s.submit(Request::from_text(2, "second ", 6));
        let mut tokens_for_1_during_prefill = 0;
        let mut first_token_2 = false;
        while !first_token_2 {
            for ev in s.tick().unwrap() {
                if let StepEvent::Token { id, .. } = ev {
                    if id == 1 && !first_token_2 {
                        tokens_for_1_during_prefill += 1;
                    }
                    if id == 2 {
                        first_token_2 = true;
                    }
                }
            }
        }
        assert!(
            tokens_for_1_during_prefill >= 2,
            "request 1 must keep decoding while request 2 prefills (got {} tokens)",
            tokens_for_1_during_prefill
        );
        s.drain().unwrap();
        let c2 = s.take_completion(2).unwrap();
        assert_eq!(s.take_completion(1).unwrap().generated_tokens, 24);
        // deferred prefill must not change the output stream
        let mut reference = sim_sched(SchedulerConfig::default());
        reference.submit(Request::from_text(2, "second ", 6));
        reference.drain().unwrap();
        assert_eq!(c2.text, reference.take_completion(2).unwrap().text);
    }

    #[test]
    fn cancel_during_async_prefill_releases_the_request() {
        let mut s = sim_sched(SchedulerConfig::default());
        s.submit(Request::from_text(1, "keeps the engine busy ", 20));
        s.tick().unwrap();
        s.engine.prefill_ticks = 1000;
        s.submit(Request::from_text(9, "slow prefill ", 4));
        s.tick().unwrap();
        assert_eq!(s.prefilling_len(), 1, "request 9 parked in prefill");
        assert!(s.cancel(9));
        assert_eq!(s.prefilling_len(), 0);
        assert_eq!(s.engine.prefills_inflight(), 0, "backend released the sequence");
        let c = s.take_completion(9).unwrap();
        assert_eq!(c.finish_reason, FinishReason::Cancelled);
        assert_eq!(c.generated_tokens, 0);
        s.drain().unwrap();
        assert_eq!(s.take_completion(1).unwrap().generated_tokens, 20);
    }

    #[test]
    fn admission_queues_on_pool_exhaustion_and_resumes() {
        // Pool of 24 pages; each request's worst-case footprint is
        // 2 layers x ceil((10 prompt + 12 new) / 4) = 12 pages, so only
        // two requests fit at once. The other two must queue (not fail,
        // not OOM) and resume as finishes release reservations.
        let backend = SimBackend::tiny_with_pool(24, false);
        let alloc = backend.allocator();
        let cfg = SchedulerConfig { max_batch: 8, admit_below: 8, ..Default::default() };
        let mut s = Scheduler::new(backend, cfg);
        for i in 1..=4u64 {
            s.submit(Request::from_text(i, "pool cap ", 12));
        }
        let mut peak_inflight = 0usize;
        let mut saw_queue_wait = false;
        while s.pending() > 0 {
            for ev in s.tick().unwrap() {
                if let StepEvent::Failed { id, error } = ev {
                    panic!("request {} failed under capacity pressure: {}", id, error);
                }
            }
            peak_inflight = peak_inflight.max(s.running_len() + s.prefilling_len());
            if s.queued_len() > 0 && s.running_len() > 0 {
                saw_queue_wait = true;
            }
        }
        assert_eq!(peak_inflight, 2, "pool covers exactly two footprints at a time");
        assert!(saw_queue_wait, "over-capacity requests must wait in the queue");
        for i in 1..=4u64 {
            let c = s.take_completion(i).expect("queued request completed after pages freed");
            assert_eq!(c.generated_tokens, 12);
        }
        let st = alloc.stats();
        assert_eq!(st.pages_reserved, 0, "all reservations returned");
        assert_eq!(st.pages_used, 0, "all pool pages freed on retire");
        assert!(st.pages_peak <= 24, "pool never exceeded its capacity");
    }

    #[test]
    fn request_larger_than_pool_fails_that_request_only() {
        let backend = SimBackend::tiny_with_pool(8, false);
        let mut s = Scheduler::new(backend, SchedulerConfig::default());
        s.submit(Request::from_text(1, "too big ", 100));
        s.submit(Request::from_text(2, "ok ", 4));
        let mut failed = None;
        while s.pending() > 0 {
            for ev in s.tick().unwrap() {
                if let StepEvent::Failed { id, error } = ev {
                    failed = Some((id, error));
                }
            }
        }
        let (id, error) = failed.expect("oversize footprint reported");
        assert_eq!(id, 1);
        assert!(error.contains("exceeds the pool"), "{}", error);
        assert!(s.take_completion(2).is_some(), "the small request still ran");
        assert!(s.take_completion(1).is_none());
        assert_eq!(s.kv_pool_stats().pages_reserved, 0);
    }

    #[test]
    fn completion_backlog_is_bounded() {
        let cfg = SchedulerConfig { completion_backlog: 4, ..Default::default() };
        let mut s = sim_sched(cfg);
        for i in 1..=12 {
            s.submit(Request::from_text(i, "x ", 1));
        }
        s.drain().unwrap();
        let kept = (1..=12).filter(|&i| s.take_completion(i).is_some()).count();
        assert_eq!(kept, 4, "unclaimed completions beyond the backlog are dropped");
    }
}
