//! Continuous-batching scheduler: admits queued requests via prefill
//! (one at a time, like vLLM's default), then interleaves batched decode
//! steps over all running sequences, padding to the compiled batch
//! buckets. Prefill-priority keeps TTFT low; decode keeps throughput up.

use std::collections::VecDeque;

use anyhow::Result;

use crate::coordinator::engine::{sample_token, Engine, SampleParams, Sequence};
use crate::coordinator::metrics::{Metrics, RequestTiming};
use crate::coordinator::tokenizer;

/// A queued generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub sample: SampleParams,
}

impl Request {
    pub fn from_text(id: u64, text: &str, max_new: usize) -> Request {
        Request {
            id,
            prompt: tokenizer::encode(text),
            max_new_tokens: max_new,
            sample: SampleParams::greedy(),
        }
    }
}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub text: String,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
}

struct Running {
    seq: Sequence,
    timing: RequestTiming,
}

/// Scheduler policy knobs.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// max sequences decoded together (bounded by compiled buckets).
    pub max_batch: usize,
    /// admit new prefills only when the running set is below this.
    pub admit_below: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { max_batch: 4, admit_below: 4 }
    }
}

pub struct Scheduler {
    pub engine: Engine,
    pub cfg: SchedulerConfig,
    queue: VecDeque<Request>,
    running: Vec<Running>,
    pub metrics: Metrics,
    pub completions: Vec<Completion>,
}

impl Scheduler {
    pub fn new(engine: Engine, cfg: SchedulerConfig) -> Scheduler {
        Scheduler {
            engine,
            cfg,
            queue: VecDeque::new(),
            running: Vec::new(),
            metrics: Metrics::new(),
            completions: Vec::new(),
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.metrics.on_arrival(req.prompt.len());
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.running.len()
    }

    /// One scheduling iteration: admit (prefill) then one decode step.
    /// Returns true if any work was done.
    pub fn tick(&mut self) -> Result<bool> {
        let mut worked = false;

        // ---- admission: prefill-priority, one per tick ----
        if self.running.len() < self.cfg.admit_below {
            if let Some(req) = self.queue.pop_front() {
                let mut timing = RequestTiming::new(req.prompt.len());
                let mut seq = self.engine.new_sequence(
                    req.id,
                    req.prompt,
                    req.max_new_tokens,
                    req.sample.clone(),
                );
                seq.eos = Some(tokenizer::EOS);
                let lg = self.engine.prefill(&mut seq)?;
                let params = seq.sample.clone();
                let tok = sample_token(&lg, &params, &mut seq.rng);
                seq.tokens.push(tok);
                if Some(tok) == seq.eos {
                    seq.finished = true;
                }
                timing.prefill_done = Some(std::time::Instant::now());
                timing.generated_tokens = 1;
                self.running.push(Running { seq, timing });
                worked = true;
            }
        }

        // ---- one batched decode step over running sequences ----
        if !self.running.is_empty() {
            let limit = self.cfg.max_batch.min(self.running.len());
            {
                let mut batch: Vec<&mut Sequence> =
                    self.running[..limit].iter_mut().map(|r| &mut r.seq).collect();
                self.engine.decode_step(&mut batch)?;
            }
            for r in &mut self.running[..limit] {
                r.timing.generated_tokens = r.seq.generated().len();
            }
            worked = true;
        }

        // ---- retire finished sequences ----
        let mut still = Vec::with_capacity(self.running.len());
        for mut r in self.running.drain(..) {
            if r.seq.done() {
                r.timing.finished = Some(std::time::Instant::now());
                self.metrics.on_complete(&r.timing);
                self.completions.push(Completion {
                    id: r.seq.id,
                    text: tokenizer::decode(r.seq.generated()),
                    tokens: r.seq.tokens.clone(),
                    prompt_tokens: r.seq.prompt_len,
                    generated_tokens: r.seq.generated().len(),
                });
            } else {
                still.push(r);
            }
        }
        self.running = still;
        Ok(worked)
    }

    /// Run until every queued request completes.
    pub fn drain(&mut self) -> Result<()> {
        while self.pending() > 0 {
            self.tick()?;
        }
        Ok(())
    }
}
