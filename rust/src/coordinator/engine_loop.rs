//! The event-driven serving front half: an engine thread that pumps
//! [`Scheduler::tick`] continuously, plus the client-facing session API.
//!
//! The PJRT runtime is single-threaded by design (`Runtime` is `!Send`),
//! so the engine — and therefore the scheduler that owns it — lives on
//! one dedicated thread, constructed *on* that thread by the closure
//! passed to [`EngineLoop::spawn`]. Everything else talks to it through
//! channels:
//!
//! * [`Submitter`] (cloneable, `Send`) submits requests and asks for
//!   metrics/engine stats. Admission is bounded: when `queue_cap`
//!   sessions are already in flight, [`Submitter::submit`] returns
//!   [`SubmitError::Busy`] immediately — the HTTP edge maps this to 429
//!   instead of queueing unboundedly.
//! * [`SessionHandle`] is the per-request side: a stream of
//!   [`SessionEvent::Token`] as tokens are sampled, terminated by
//!   `Done` or `Error`, plus [`SessionHandle::cancel`] which retires
//!   the sequence mid-flight and releases its GPU slots and CPU pool
//!   pages. Dropping the handle cancels implicitly: the loop notices the
//!   dead channel on the next token and cancels the sequence.
//!
//! The loop blocks on the command channel while idle (no spinning) and
//! drains commands between ticks while busy, so multiple in-flight
//! requests genuinely share decode batches. When the scheduler is
//! configured with `microbatch_min`, a large running set is decoded as
//! up to `max_lanes` pipelined microbatch lanes per tick
//! (`Backend::decode_step_lanes`), which a pooled-dispatch engine
//! overlaps across its executor workers — several decode microbatches
//! in flight from one engine thread, with prefill chunks interleaved.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError, TryRecvError};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::ModelConfig;
use crate::coordinator::engine::{Backend, EngineStats};
use crate::coordinator::scheduler::{Completion, Request, Scheduler, StepEvent};
use crate::util::fault::panic_message;

/// What a session's client receives, in order: zero or more `Token`s,
/// then exactly one `Done` or `Error` (unless the engine loop shuts
/// down first, which closes the channel).
#[derive(Debug, Clone)]
pub enum SessionEvent {
    /// One generated token: its index, id, and decoded text.
    Token {
        /// Zero-based position within the generated output.
        index: usize,
        /// Token id.
        token: i32,
        /// Decoded text of this token.
        text: String,
    },
    /// Terminal success: the finished completion.
    Done(Completion),
    /// Terminal failure: the engine's error message.
    Error(String),
}

enum Command {
    Submit { req: Request, events: mpsc::Sender<SessionEvent>, arrived: Instant },
    Cancel(u64),
    Metrics(mpsc::Sender<String>),
    Stats(mpsc::Sender<EngineStats>),
    Model(mpsc::Sender<ModelConfig>),
    /// Stop accepting new sessions and finish the in-flight ones; any
    /// still running at the deadline are cancelled.
    Drain { deadline: Instant },
    Shutdown,
}

/// Why a submission was rejected.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// Admission queue full: `in_flight` sessions against `cap`.
    /// Backpressure — retry later (HTTP 429).
    Busy { in_flight: usize, cap: usize },
    /// The loop is draining for shutdown: in-flight sessions finish,
    /// new ones are refused.
    Draining,
    /// The engine loop has shut down.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy { in_flight, cap } => {
                write!(f, "server busy: {} sessions in flight (cap {})", in_flight, cap)
            }
            SubmitError::Draining => write!(f, "server draining; not accepting new sessions"),
            SubmitError::Closed => write!(f, "engine loop is not running"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Terminal failure of a session wait.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SessionError {
    /// The engine reported a per-request or global error.
    Engine(String),
    /// The engine loop went away before the session finished.
    Disconnected,
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionError::Engine(e) => write!(f, "engine error: {}", e),
            SessionError::Disconnected => write!(f, "engine loop shut down mid-session"),
        }
    }
}

impl std::error::Error for SessionError {}

/// Engine-loop policy knobs.
#[derive(Debug, Clone)]
pub struct LoopConfig {
    /// Max sessions in flight (queued + running) before `submit`
    /// returns [`SubmitError::Busy`].
    pub queue_cap: usize,
    /// How many times the supervisor rebuilds the scheduler/engine after
    /// a tick panic or engine-global error before staying down. Each
    /// restart fails the in-flight sessions (terminal `Error` events,
    /// KV released) and re-opens admission on the fresh engine.
    pub max_engine_restarts: u64,
}

impl Default for LoopConfig {
    fn default() -> Self {
        LoopConfig { queue_cap: 64, max_engine_restarts: 2 }
    }
}

/// Coarse serving-health state surfaced on `/healthz`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    /// Fully healthy: no restarts, no degraded subsystems.
    Ok,
    /// Still serving, but on a degradation-ladder rung: the engine was
    /// restarted, an executor worker is dead or being routed around, or
    /// recall fell back to the serial path.
    Degraded,
    /// Not serving: the loop exited (shutdown, or restart budget
    /// exhausted).
    Down,
}

impl Health {
    /// Lowercase wire form used on `/healthz`.
    pub fn as_str(&self) -> &'static str {
        match self {
            Health::Ok => "ok",
            Health::Degraded => "degraded",
            Health::Down => "down",
        }
    }

    fn from_u8(v: u8) -> Health {
        match v {
            0 => Health::Ok,
            1 => Health::Degraded,
            _ => Health::Down,
        }
    }
}

/// Cloneable, thread-safe handle for submitting work to the engine
/// loop. Every accepted submission gets a fresh request id.
#[derive(Clone)]
pub struct Submitter {
    tx: mpsc::Sender<Command>,
    in_flight: Arc<AtomicUsize>,
    next_id: Arc<AtomicU64>,
    draining: Arc<AtomicBool>,
    queue_cap: usize,
    health: Arc<AtomicU8>,
    restarts: Arc<AtomicU64>,
}

impl Submitter {
    /// Submit a request (its `id` is replaced with a fresh one).
    /// Returns immediately: `Busy` when the admission queue is full,
    /// `Draining` once a drain began, `Closed` when the loop is gone.
    pub fn submit(&self, mut req: Request) -> Result<SessionHandle, SubmitError> {
        if self.draining.load(Ordering::SeqCst) {
            return Err(SubmitError::Draining);
        }
        let prev = self.in_flight.fetch_add(1, Ordering::SeqCst);
        if prev >= self.queue_cap {
            self.in_flight.fetch_sub(1, Ordering::SeqCst);
            return Err(SubmitError::Busy { in_flight: prev, cap: self.queue_cap });
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        req.id = id;
        let (tx, rx) = mpsc::channel();
        let arrived = Instant::now();
        if self.tx.send(Command::Submit { req, events: tx, arrived }).is_err() {
            self.in_flight.fetch_sub(1, Ordering::SeqCst);
            return Err(SubmitError::Closed);
        }
        Ok(SessionHandle { id, events: rx, cmd: self.tx.clone() })
    }

    /// Convenience: submit a plain text prompt.
    pub fn submit_text(
        &self,
        prompt: &str,
        max_tokens: usize,
    ) -> Result<SessionHandle, SubmitError> {
        self.submit(Request::from_text(0, prompt, max_tokens))
    }

    /// Sessions currently queued or running (the admission gauge).
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// The admission cap this submitter enforces (sessions in flight
    /// before `submit` returns `Busy`). The HTTP edge sizes its
    /// connection-thread cap from this.
    pub fn queue_cap(&self) -> usize {
        self.queue_cap
    }

    /// Current serving-health state (updated by the loop thread; `Down`
    /// once the loop exits for good).
    pub fn health(&self) -> Health {
        Health::from_u8(self.health.load(Ordering::SeqCst))
    }

    /// Engine restarts performed by the supervisor so far.
    pub fn engine_restarts(&self) -> u64 {
        self.restarts.load(Ordering::SeqCst)
    }

    /// One-line serving metrics report from the loop's scheduler, with
    /// the supervisor's health state appended.
    pub fn metrics_report(&self) -> Result<String, SubmitError> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Command::Metrics(tx)).map_err(|_| SubmitError::Closed)?;
        let line = rx.recv().map_err(|_| SubmitError::Closed)?;
        Ok(format!("{} health={}", line, self.health().as_str()))
    }

    /// Snapshot of the engine's cumulative stats.
    pub fn engine_stats(&self) -> Result<EngineStats, SubmitError> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Command::Stats(tx)).map_err(|_| SubmitError::Closed)?;
        rx.recv().map_err(|_| SubmitError::Closed)
    }

    /// The model configuration of the backend behind this loop (a
    /// router reads `page_size` from it to key prefix-affinity hashes).
    pub fn model_config(&self) -> Result<ModelConfig, SubmitError> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Command::Model(tx)).map_err(|_| SubmitError::Closed)?;
        rx.recv().map_err(|_| SubmitError::Closed)
    }

    /// Ask the loop to stop. In-flight sessions are cancelled and their
    /// event channels closed.
    pub fn shutdown(&self) {
        let _ = self.tx.send(Command::Shutdown);
    }

    /// Graceful drain: new submissions are refused (`Draining`)
    /// immediately, in-flight sessions keep decoding to completion, and
    /// whatever still runs after `timeout` is cancelled as the loop
    /// exits. Metrics/stats queries keep answering during the drain.
    pub fn drain(&self, timeout: Duration) {
        self.drain_until(Instant::now() + timeout);
    }

    /// Like [`Submitter::drain`], with an absolute deadline — a
    /// multi-replica router fans one shared deadline out to every
    /// replica so set-wide drains run concurrently, not stacked.
    pub fn drain_until(&self, deadline: Instant) {
        self.draining.store(true, Ordering::SeqCst);
        let _ = self.tx.send(Command::Drain { deadline });
    }
}

/// One client's view of an in-flight generation.
pub struct SessionHandle {
    id: u64,
    events: mpsc::Receiver<SessionEvent>,
    cmd: mpsc::Sender<Command>,
}

impl SessionHandle {
    /// The loop-assigned session id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Next event, blocking. `None` when the engine loop is gone.
    pub fn next_event(&self) -> Option<SessionEvent> {
        self.events.recv().ok()
    }

    /// Next event with a timeout (lets callers interleave disconnect
    /// polling with event consumption).
    pub fn recv_timeout(&self, d: Duration) -> Result<SessionEvent, RecvTimeoutError> {
        self.events.recv_timeout(d)
    }

    /// Cancel this session: the sequence is retired mid-flight and its
    /// KV resources are released. A `Done` event with
    /// `finish_reason == Cancelled` follows (if the session was still
    /// alive).
    pub fn cancel(&self) {
        let _ = self.cmd.send(Command::Cancel(self.id));
    }

    /// Block until the session ends, discarding token events.
    pub fn wait(self) -> Result<Completion, SessionError> {
        loop {
            match self.events.recv() {
                Ok(SessionEvent::Token { .. }) => {}
                Ok(SessionEvent::Done(c)) => return Ok(c),
                Ok(SessionEvent::Error(e)) => return Err(SessionError::Engine(e)),
                Err(_) => return Err(SessionError::Disconnected),
            }
        }
    }
}

/// The engine thread: owns the scheduler (and through it the `!Send`
/// engine), pumps ticks, and routes step events to session channels.
pub struct EngineLoop {
    submitter: Submitter,
    handle: thread::JoinHandle<()>,
}

impl EngineLoop {
    /// Spawn the engine thread. `make` runs *on* that thread (the
    /// engine need not be `Send`); spawn blocks until construction
    /// finishes and propagates its error if it fails. `make` is `FnMut`
    /// because the supervisor re-invokes it to rebuild the scheduler
    /// after an engine panic (up to `cfg.max_engine_restarts` times).
    pub fn spawn<B, F>(cfg: LoopConfig, make: F) -> Result<EngineLoop>
    where
        B: Backend + 'static,
        F: FnMut() -> Result<Scheduler<B>> + Send + 'static,
    {
        let (cmd_tx, cmd_rx) = mpsc::channel();
        let in_flight = Arc::new(AtomicUsize::new(0));
        let health = Arc::new(AtomicU8::new(0));
        let restarts = Arc::new(AtomicU64::new(0));
        let counter = in_flight.clone();
        let (health_w, restarts_w) = (health.clone(), restarts.clone());
        let max_restarts = cfg.max_engine_restarts;
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let handle = thread::Builder::new()
            .name("freekv-engine".into())
            .spawn(move || {
                let mut make = make;
                let sched = match make() {
                    Ok(s) => {
                        let _ = ready_tx.send(Ok(()));
                        s
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        health_w.store(Health::Down as u8, Ordering::SeqCst);
                        return;
                    }
                };
                supervise(sched, make, cmd_rx, &counter, &health_w, &restarts_w, max_restarts);
            })
            .expect("spawn engine thread");
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(EngineLoop {
                submitter: Submitter {
                    tx: cmd_tx,
                    in_flight,
                    next_id: Arc::new(AtomicU64::new(1)),
                    draining: Arc::new(AtomicBool::new(false)),
                    queue_cap: cfg.queue_cap.max(1),
                    health,
                    restarts,
                },
                handle,
            }),
            Ok(Err(e)) => {
                let _ = handle.join();
                Err(anyhow!("engine startup failed: {}", e))
            }
            Err(_) => {
                let _ = handle.join();
                Err(anyhow!("engine thread died during startup"))
            }
        }
    }

    /// A cloneable handle for submitting sessions to this loop.
    pub fn submitter(&self) -> Submitter {
        self.submitter.clone()
    }

    /// Stop the loop and join the engine thread. In-flight sessions are
    /// cancelled.
    pub fn shutdown(self) {
        self.submitter.shutdown();
        let _ = self.handle.join();
    }

    /// Graceful shutdown: refuse new sessions, finish the running ones
    /// (up to `timeout`), then join the engine thread. Sessions still
    /// running at the deadline are cancelled.
    pub fn shutdown_graceful(self, timeout: Duration) {
        self.submitter.drain(timeout);
        let _ = self.handle.join();
    }

    /// Join the engine thread without sending any command — used by
    /// [`crate::coordinator::router::ReplicaSet`] after fanning a
    /// shared drain deadline out to every replica (a per-replica
    /// `shutdown_graceful` would stack the deadlines).
    pub(crate) fn join(self) {
        let _ = self.handle.join();
    }
}

struct Sessions {
    channels: HashMap<u64, mpsc::Sender<SessionEvent>>,
    in_flight: Arc<AtomicUsize>,
}

impl Sessions {
    /// Remove a session and release its admission slot.
    fn close(&mut self, id: u64) -> Option<mpsc::Sender<SessionEvent>> {
        let tx = self.channels.remove(&id)?;
        self.in_flight.fetch_sub(1, Ordering::SeqCst);
        Some(tx)
    }
}

/// Why [`run_loop`] returned.
enum LoopExit {
    /// Intentional stop: shutdown command, drain finished, or every
    /// submitter hung up.
    Stop,
    /// The engine failed mid-tick (panic or engine-global error). The
    /// in-flight sessions have already been failed and their admission
    /// slots released; the scheduler's state is arbitrary.
    Failed(String),
}

/// The supervisor: pumps [`run_loop`], and on an engine failure rebuilds
/// the scheduler via `make` (carrying the serving metrics over) up to
/// `max_restarts` times before staying down. Restart teardown happens
/// inside `run_loop` (fail in-flight sessions, release KV, re-open
/// admission); this function only owns the rebuild.
fn supervise<B: Backend>(
    mut sched: Scheduler<B>,
    mut make: impl FnMut() -> Result<Scheduler<B>>,
    rx: mpsc::Receiver<Command>,
    in_flight: &Arc<AtomicUsize>,
    health: &Arc<AtomicU8>,
    restarts: &Arc<AtomicU64>,
    max_restarts: u64,
) {
    let mut sessions = Sessions { channels: HashMap::new(), in_flight: in_flight.clone() };
    // Set by Command::Drain; survives engine restarts so a drain begun
    // before a panic still converges.
    let mut draining: Option<Instant> = None;
    let mut healthy_exit = true;
    loop {
        match run_loop(&mut sched, &rx, &mut sessions, &mut draining, health, restarts) {
            LoopExit::Stop => break,
            LoopExit::Failed(msg) => {
                let used = restarts.load(Ordering::SeqCst);
                if used >= max_restarts {
                    eprintln!(
                        "[freekv] engine failed ({}); restart budget ({}) exhausted — down",
                        msg, max_restarts
                    );
                    healthy_exit = false;
                    break;
                }
                // Rebuild on this same thread; the serving metrics
                // (request/failure counters, latency histograms) carry
                // across so /metrics reflects the process, not the
                // incarnation.
                let metrics = std::mem::take(&mut sched.metrics);
                match make() {
                    Ok(mut fresh) => {
                        restarts.fetch_add(1, Ordering::SeqCst);
                        fresh.metrics = metrics;
                        fresh.metrics.engine_restarts = restarts.load(Ordering::SeqCst);
                        let wedged = std::mem::replace(&mut sched, fresh);
                        // The wedged scheduler's drop path may panic
                        // again (its invariants are gone); contain it.
                        let _ = catch_unwind(AssertUnwindSafe(move || drop(wedged)));
                        health.store(Health::Degraded as u8, Ordering::SeqCst);
                        eprintln!(
                            "[freekv] engine failed ({}); restarted ({}/{})",
                            msg,
                            restarts.load(Ordering::SeqCst),
                            max_restarts
                        );
                    }
                    Err(e) => {
                        eprintln!("[freekv] engine restart failed: {e:#} — down");
                        healthy_exit = false;
                        break;
                    }
                }
            }
        }
    }
    health.store(Health::Down as u8, Ordering::SeqCst);
    // Shutdown: retire in-flight sequences so nothing strands on the
    // recall worker, then drop the session channels (clients see EOF).
    // On the unhealthy path the scheduler is wedged and its in-flight
    // set was already failed; skip touching it further.
    if healthy_exit {
        for id in sched.active_ids() {
            sched.cancel(id);
            let _ = sched.take_completion(id);
            sessions.close(id);
        }
    }
}

/// Fail every in-flight session with `msg` after an engine fault:
/// terminal `Error` events (the request is NOT silently lost), KV pages
/// and reservations released through the normal retire paths where the
/// wedged engine still allows it, admission slots re-opened.
fn fail_inflight<B: Backend>(sched: &mut Scheduler<B>, sessions: &mut Sessions, msg: &str) {
    let ids = catch_unwind(AssertUnwindSafe(|| sched.active_ids())).unwrap_or_default();
    for id in ids {
        // abort() walks the normal retire path (drain recall worker,
        // drop sequence, release reservation) and counts the request as
        // failed. A wedged engine may panic again inside it — contain
        // that and at least release the admission-charged reservation.
        if catch_unwind(AssertUnwindSafe(|| sched.abort(id))).is_err() {
            let _ = catch_unwind(AssertUnwindSafe(|| sched.engine.kv_release(id)));
            sched.metrics.on_failed();
        }
        if let Some(tx) = sessions.close(id) {
            let _ = tx.send(SessionEvent::Error(msg.to_string()));
        }
    }
    // Sessions whose submit command is still queued in the channel keep
    // their slots; the restarted loop admits them normally.
}

fn run_loop<B: Backend>(
    sched: &mut Scheduler<B>,
    rx: &mpsc::Receiver<Command>,
    sessions: &mut Sessions,
    draining: &mut Option<Instant>,
    health: &Arc<AtomicU8>,
    restarts: &Arc<AtomicU64>,
) -> LoopExit {
    'outer: loop {
        // Publish health: Degraded while restarted or while the engine
        // reports a degradation-ladder rung, Ok otherwise.
        let degraded = restarts.load(Ordering::SeqCst) > 0 || sched.engine.stats().degraded();
        let state = if degraded { Health::Degraded } else { Health::Ok };
        health.store(state as u8, Ordering::SeqCst);
        if let Some(deadline) = *draining {
            if sched.pending() == 0 || Instant::now() >= deadline {
                return LoopExit::Stop;
            }
        }
        // Idle: block until the next command instead of spinning.
        if sched.pending() == 0 {
            match rx.recv() {
                Ok(cmd) => {
                    if !handle_command(sched, sessions, cmd, draining) {
                        return LoopExit::Stop;
                    }
                }
                Err(_) => return LoopExit::Stop, // every Submitter is gone
            }
        }
        // Busy: drain whatever has arrived, then tick.
        loop {
            match rx.try_recv() {
                Ok(cmd) => {
                    if !handle_command(sched, sessions, cmd, draining) {
                        return LoopExit::Stop;
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    if sched.pending() == 0 {
                        return LoopExit::Stop;
                    }
                    break;
                }
            }
        }
        if sched.pending() > 0 {
            match catch_unwind(AssertUnwindSafe(|| sched.tick())) {
                Ok(Ok(events)) => route_events(sched, sessions, events),
                Ok(Err(e)) => {
                    // Engine-global decode error: fail every live
                    // session loudly and let the supervisor decide
                    // whether to rebuild the engine.
                    let msg = format!("engine error: {e:#}");
                    fail_inflight(sched, sessions, &msg);
                    break 'outer LoopExit::Failed(msg);
                }
                Err(payload) => {
                    // Engine-thread panic: same ladder, scarier cause.
                    let msg = format!("engine panicked: {}", panic_message(payload.as_ref()));
                    fail_inflight(sched, sessions, &msg);
                    break 'outer LoopExit::Failed(msg);
                }
            }
        }
    }
}

/// Returns false when the loop should stop.
fn handle_command<B: Backend>(
    sched: &mut Scheduler<B>,
    sessions: &mut Sessions,
    cmd: Command,
    draining: &mut Option<Instant>,
) -> bool {
    match cmd {
        Command::Submit { events, .. } if draining.is_some() => {
            // Raced the drain flag: refuse and release the admission
            // slot this submission took (it never reaches the map).
            let _ = events.send(SessionEvent::Error(SubmitError::Draining.to_string()));
            sessions.in_flight.fetch_sub(1, Ordering::SeqCst);
            true
        }
        Command::Submit { req, events, arrived } => {
            sessions.channels.insert(req.id, events);
            sched.submit_arrived(req, arrived);
            true
        }
        Command::Cancel(id) => {
            if sched.cancel(id) {
                let done = sched.take_completion(id);
                if let Some(tx) = sessions.close(id) {
                    if let Some(c) = done {
                        let _ = tx.send(SessionEvent::Done(c));
                    }
                }
            }
            true
        }
        Command::Metrics(reply) => {
            // one line: serving metrics + the shared KV pool gauges
            // (including the persistent prefix-cache tier counters)
            let kv = sched.kv_pool_stats();
            let report = format!(
                "{} kv_pages_total={} kv_pages_used={} kv_pages_shared={} \
                 kv_pages_reserved={} prefix_hits={} kv_cpu_bytes={} kv_gpu_bytes={} \
                 kv_pages_retained={} kv_retained_hits={} kv_retained_evictions={} \
                 kv_bytes_saved={} prefill_tokens_saved={} \
                 kv_shard_lock_waits={} kv_shard_lock_wait_secs={:.6} \
                 kv_meta_lock_waits={} kv_meta_lock_wait_secs={:.6}",
                sched.metrics.report(),
                kv.pages_capacity,
                kv.pages_used,
                kv.pages_shared,
                kv.pages_reserved,
                kv.prefix_hits,
                kv.cpu_bytes_used,
                kv.gpu_bytes_used,
                kv.pages_retained,
                kv.retained_hits,
                kv.retained_evictions,
                kv.bytes_saved,
                sched.engine.stats().prefill_tokens_saved,
                kv.shard_lock_waits,
                kv.shard_lock_wait_secs,
                kv.meta_lock_waits,
                kv.meta_lock_wait_secs
            );
            let _ = reply.send(report);
            true
        }
        Command::Stats(reply) => {
            let _ = reply.send(sched.engine.stats().clone());
            true
        }
        Command::Model(reply) => {
            let _ = reply.send(sched.engine.model().clone());
            true
        }
        Command::Drain { deadline } => {
            *draining = Some(deadline);
            true
        }
        Command::Shutdown => false,
    }
}

fn route_events<B: Backend>(
    sched: &mut Scheduler<B>,
    sessions: &mut Sessions,
    events: Vec<StepEvent>,
) {
    for ev in events {
        match ev {
            StepEvent::Token { id, index, token, text } => {
                let dead = match sessions.channels.get(&id) {
                    Some(tx) => tx.send(SessionEvent::Token { index, token, text }).is_err(),
                    None => false,
                };
                if dead {
                    // Client went away (handle dropped without cancel):
                    // retire the sequence and reclaim the slot.
                    sessions.close(id);
                    sched.cancel(id);
                    let _ = sched.take_completion(id);
                }
            }
            StepEvent::Finished { id } => {
                let done = sched.take_completion(id);
                if let Some(tx) = sessions.close(id) {
                    if let Some(c) = done {
                        let _ = tx.send(SessionEvent::Done(c));
                    }
                }
            }
            StepEvent::Failed { id, error } => {
                if let Some(tx) = sessions.close(id) {
                    let _ = tx.send(SessionEvent::Error(error));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::{FinishReason, SchedulerConfig};
    use crate::coordinator::sim_backend::SimBackend;

    fn spawn_sim(queue_cap: usize, step_delay_ms: u64) -> EngineLoop {
        EngineLoop::spawn(LoopConfig { queue_cap, ..Default::default() }, move || {
            let mut b = SimBackend::tiny();
            b.step_delay = Duration::from_millis(step_delay_ms);
            let cfg = SchedulerConfig { max_batch: 8, admit_below: 8, ..Default::default() };
            Ok(Scheduler::new(b, cfg))
        })
        .expect("sim loop spawns")
    }

    #[test]
    fn sessions_stream_tokens_then_done() {
        let el = spawn_sim(8, 0);
        let sub = el.submitter();
        let h = sub.submit_text("engine loop test ", 6).unwrap();
        let mut tokens = 0;
        let done = loop {
            match h.next_event().expect("loop alive") {
                SessionEvent::Token { index, .. } => {
                    assert_eq!(index, tokens);
                    tokens += 1;
                }
                SessionEvent::Done(c) => break c,
                SessionEvent::Error(e) => panic!("unexpected error: {}", e),
            }
        };
        assert_eq!(tokens, 6);
        assert_eq!(done.generated_tokens, 6);
        assert_eq!(done.finish_reason, FinishReason::Length);
        assert_eq!(sub.in_flight(), 0, "admission slot released");
        let report = sub.metrics_report().unwrap();
        assert!(report.contains("completed=1"), "{}", report);
        el.shutdown();
    }

    #[test]
    fn microbatched_sessions_all_complete_with_identical_streams() {
        // Four concurrent sessions over a microbatching scheduler: every
        // stream must match the single-batch result (the sim stream is a
        // pure function of the prompt), proving the pair dispatch path
        // is invisible to clients.
        let el = EngineLoop::spawn(LoopConfig { queue_cap: 8, ..Default::default() }, || {
            let cfg = SchedulerConfig {
                max_batch: 8,
                admit_below: 8,
                microbatch_min: 4,
                ..Default::default()
            };
            Ok(Scheduler::new(SimBackend::tiny(), cfg))
        })
        .expect("sim loop spawns");
        let sub = el.submitter();
        let handles: Vec<_> = (0..4)
            .map(|i| sub.submit_text(&format!("microbatch client {} ", i), 16).unwrap())
            .collect();
        let texts: Vec<String> =
            handles.into_iter().map(|h| h.wait().unwrap().text).collect();
        for (i, text) in texts.iter().enumerate() {
            assert_eq!(text.len(), 16, "client {} got {:?}", i, text);
        }
        // same prompt solo must produce the same text as under the pair
        let solo = sub.submit_text("microbatch client 0 ", 16).unwrap().wait().unwrap();
        assert_eq!(solo.text, texts[0], "microbatching changed a client's stream");
        el.shutdown();
    }

    #[test]
    fn submit_after_shutdown_is_closed() {
        let el = spawn_sim(8, 0);
        let sub = el.submitter();
        el.shutdown();
        assert!(matches!(sub.submit_text("x", 2), Err(SubmitError::Closed)));
        assert!(sub.metrics_report().is_err());
    }

    #[test]
    fn busy_when_queue_cap_reached() {
        let el = spawn_sim(1, 30);
        let sub = el.submitter();
        let h = sub.submit_text("occupies the only slot ", 20).unwrap();
        let err = sub.submit_text("rejected ", 2).unwrap_err();
        assert!(matches!(err, SubmitError::Busy { cap: 1, .. }), "{:?}", err);
        let c = h.wait().unwrap();
        assert_eq!(c.generated_tokens, 20);
        // slot released: next submit is admitted
        let h2 = sub.submit_text("admitted now ", 2).unwrap();
        assert!(h2.wait().is_ok());
        el.shutdown();
    }

    #[test]
    fn explicit_cancel_returns_cancelled_completion() {
        let el = spawn_sim(4, 20);
        let sub = el.submitter();
        let h = sub.submit_text("long running request ", 500).unwrap();
        // wait for the first token so the sequence is mid-flight
        match h.next_event().expect("alive") {
            SessionEvent::Token { .. } => {}
            other => panic!("expected token, got {:?}", other),
        }
        h.cancel();
        let c = loop {
            match h.next_event().expect("alive") {
                SessionEvent::Token { .. } => {}
                SessionEvent::Done(c) => break c,
                SessionEvent::Error(e) => panic!("unexpected error: {}", e),
            }
        };
        assert_eq!(c.finish_reason, FinishReason::Cancelled);
        assert!(c.generated_tokens < 500);
        assert_eq!(sub.in_flight(), 0);
        el.shutdown();
    }

    #[test]
    fn graceful_drain_finishes_inflight_sessions() {
        let el = spawn_sim(8, 5);
        let sub = el.submitter();
        let h = sub.submit_text("drain finishes me ", 12).unwrap();
        // wait for the first token so the session is genuinely running
        match h.next_event().expect("alive") {
            SessionEvent::Token { .. } => {}
            other => panic!("expected token, got {:?}", other),
        }
        sub.drain(Duration::from_secs(10));
        // new work is refused immediately
        assert!(matches!(sub.submit_text("late ", 2), Err(SubmitError::Draining)));
        // ...but the in-flight session runs to a natural completion
        let c = h.wait().expect("drained session completes");
        assert_eq!(c.finish_reason, FinishReason::Length);
        assert_eq!(c.generated_tokens, 12);
        el.shutdown();
    }

    #[test]
    fn drain_deadline_cancels_stragglers() {
        let el = spawn_sim(4, 30);
        let sub = el.submitter();
        let h = sub.submit_text("will outlive the deadline ", 10_000).unwrap();
        match h.next_event().expect("alive") {
            SessionEvent::Token { .. } => {}
            other => panic!("expected token, got {:?}", other),
        }
        // Deadline far shorter than the generation: the loop must stop
        // anyway, closing the session channel.
        el.shutdown_graceful(Duration::from_millis(100));
        let t0 = std::time::Instant::now();
        loop {
            match h.next_event() {
                Some(SessionEvent::Token { .. }) => {}
                Some(SessionEvent::Done(_)) | None => break, // cancelled or channel closed
                Some(SessionEvent::Error(e)) => panic!("unexpected error: {}", e),
            }
            assert!(t0.elapsed() < Duration::from_secs(10), "drain deadline ignored");
        }
    }

    #[test]
    fn dropped_handle_cancels_session() {
        let el = spawn_sim(4, 10);
        let sub = el.submitter();
        let h = sub.submit_text("abandoned request ", 500).unwrap();
        drop(h);
        // the loop notices the dead channel on the next token
        let t0 = std::time::Instant::now();
        while sub.in_flight() != 0 {
            assert!(t0.elapsed() < Duration::from_secs(5), "session never reclaimed");
            thread::sleep(Duration::from_millis(10));
        }
        el.shutdown();
    }
}
