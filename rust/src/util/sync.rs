//! Poison-aware locking. `clippy.toml` bans bare `Mutex::lock()` in
//! this crate: a panic while a lock is held (a worker job blowing up,
//! an injected fault) poisons the mutex, and every `.lock().unwrap()`
//! downstream then cascades the panic through unrelated threads. Call
//! sites must either recover deliberately (this helper, or a bespoke
//! recovery like the page allocator's `lock_timed`) or map the error
//! explicitly.

use std::sync::{Mutex, MutexGuard};

/// Lock a mutex, deliberately recovering from poisoning: the data is
/// still returned, on the caller's judgement that its invariants hold
/// (or are re-validated) regardless of where the poisoning panic hit.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    #[allow(clippy::disallowed_methods)] // the one deliberate recovery point
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    #[test]
    fn recovers_a_poisoned_mutex() {
        let m = Mutex::new(3usize);
        let r = catch_unwind(AssertUnwindSafe(|| {
            let _g = lock_unpoisoned(&m);
            panic!("poison it");
        }));
        assert!(r.is_err());
        assert!(m.is_poisoned());
        *lock_unpoisoned(&m) += 1;
        assert_eq!(*lock_unpoisoned(&m), 4);
    }
}
