//! Deterministic fault injection for the serving stack.
//!
//! Every helper thread FreeKV moves work onto — the recall transfer
//! worker, the executor-pool workers, the engine loop itself — is a
//! fault domain, and each domain's degradation ladder (see README,
//! "Failure model & degradation ladder") is only trustworthy if it is
//! *exercised*. A [`FaultPlan`] is a seeded schedule of failures at
//! named sites: each site keeps an atomic call counter, and a call
//! whose index is in the site's precomputed fire set injects the fault.
//! The same seed therefore produces the same faults at the same points
//! on every run, across threads, independent of timing — chaos tests
//! are reproducible and CI failures replayable.
//!
//! Components hold an `Option<Arc<FaultPlan>>`; `None` is the
//! production configuration and costs one branch per site. A present
//! but *empty* plan ([`FaultPlan::disabled`]) fires nothing and must be
//! behaviourally identical to `None` — the bit-identical-when-disabled
//! property the fault tests assert.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::rng::Rng;

/// Where a fault can be injected. Each site is checked by exactly one
/// component, so schedules never interfere across domains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// Executor worker: the job attempt fails with a transient error
    /// (exercises the one-deterministic-retry ladder).
    ExecJobError,
    /// Executor worker: the worker thread drains its queue with errors
    /// and exits (exercises route-around + respawn).
    ExecWorkerDeath,
    /// Recall worker: stops processing and bounces every job back
    /// untouched (exercises the serial-recall fallback).
    RecallWorkerDeath,
    /// Transfer engine: a recall pays an artificial stall (exercises
    /// exposed-time accounting under a slow link).
    SlowTransfer,
    /// Engine thread: `decode_step` panics (exercises the engine-loop
    /// supervisor restart).
    EnginePanic,
    /// Engine thread: `decode_step` returns a transient error.
    DecodeError,
    /// A panic raised while holding the page-allocator lock (exercises
    /// poisoned-lock recovery end to end).
    AllocPanic,
}

impl FaultSite {
    /// Every fault site, in enum order.
    pub const ALL: [FaultSite; 7] = [
        FaultSite::ExecJobError,
        FaultSite::ExecWorkerDeath,
        FaultSite::RecallWorkerDeath,
        FaultSite::SlowTransfer,
        FaultSite::EnginePanic,
        FaultSite::DecodeError,
        FaultSite::AllocPanic,
    ];

    fn idx(self) -> usize {
        self as usize
    }

    /// Kebab-case name used in chaos-test logs.
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::ExecJobError => "exec-job-error",
            FaultSite::ExecWorkerDeath => "exec-worker-death",
            FaultSite::RecallWorkerDeath => "recall-worker-death",
            FaultSite::SlowTransfer => "slow-transfer",
            FaultSite::EnginePanic => "engine-panic",
            FaultSite::DecodeError => "decode-error",
            FaultSite::AllocPanic => "alloc-panic",
        }
    }
}

/// One site's schedule: sorted call indices that fire, plus live
/// counters. Immutable after construction, so checks are lock-free.
#[derive(Debug, Default)]
struct SiteSchedule {
    fire_at: Vec<u64>,
    calls: AtomicU64,
    fired: AtomicU64,
}

/// A seeded, deterministic schedule of injected failures. Cheap to
/// share (`Arc`) across the engine thread, pool workers, and the recall
/// worker; thread-safe without locks.
#[derive(Debug, Default)]
pub struct FaultPlan {
    sites: [SiteSchedule; FaultSite::ALL.len()],
    injected: AtomicU64,
    /// Stall applied when `SlowTransfer` fires.
    slow: Duration,
}

impl FaultPlan {
    /// A plan that never fires. Present-but-disabled must be
    /// behaviourally identical to no plan at all.
    pub fn disabled() -> FaultPlan {
        FaultPlan { slow: Duration::from_millis(2), ..Default::default() }
    }

    /// Targeted plan: fire each `(site, call_index)` exactly once
    /// (indices are per-site, counted from 0).
    pub fn events(events: &[(FaultSite, u64)]) -> FaultPlan {
        let mut plan = FaultPlan::disabled();
        for &(site, at) in events {
            plan.sites[site.idx()].fire_at.push(at);
        }
        for s in plan.sites.iter_mut() {
            s.fire_at.sort_unstable();
            s.fire_at.dedup();
        }
        plan
    }

    /// The default chaotic mixture for a seed: a handful of faults per
    /// site, scheduled over the early calls so short test runs reach
    /// them. Same seed, same schedule, forever.
    pub fn chaos(seed: u64) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA17_F1A6);
        let mut events = Vec::new();
        let mut draw = |site: FaultSite, count: usize, horizon: u64, out: &mut Vec<(FaultSite, u64)>| {
            for _ in 0..count {
                out.push((site, rng.below(horizon as usize) as u64));
            }
        };
        draw(FaultSite::ExecJobError, 1 + rng.below(3), 96, &mut events);
        draw(FaultSite::ExecWorkerDeath, rng.below(2), 64, &mut events);
        draw(FaultSite::RecallWorkerDeath, rng.below(2), 48, &mut events);
        draw(FaultSite::SlowTransfer, 2 + rng.below(4), 64, &mut events);
        draw(FaultSite::EnginePanic, 1 + rng.below(2), 48, &mut events);
        draw(FaultSite::DecodeError, 1 + rng.below(2), 48, &mut events);
        draw(FaultSite::AllocPanic, rng.below(2), 64, &mut events);
        FaultPlan::events(&events)
    }

    /// Count this call against `site` and report whether it fires. The
    /// no-fault fast path is one atomic increment plus a binary search
    /// of an (almost always empty) sorted list.
    pub fn check(&self, site: FaultSite) -> bool {
        let s = &self.sites[site.idx()];
        let i = s.calls.fetch_add(1, Ordering::SeqCst);
        if s.fire_at.binary_search(&i).is_ok() {
            s.fired.fetch_add(1, Ordering::SeqCst);
            self.injected.fetch_add(1, Ordering::SeqCst);
            true
        } else {
            false
        }
    }

    /// Total faults injected so far, across all sites.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::SeqCst)
    }

    /// Faults injected at one site so far.
    pub fn fired(&self, site: FaultSite) -> u64 {
        self.sites[site.idx()].fired.load(Ordering::SeqCst)
    }

    /// Calls observed at one site so far.
    pub fn calls(&self, site: FaultSite) -> u64 {
        self.sites[site.idx()].calls.load(Ordering::SeqCst)
    }

    /// True when no site can ever fire.
    pub fn is_disabled(&self) -> bool {
        self.sites.iter().all(|s| s.fire_at.is_empty())
    }

    /// The stall a fired `SlowTransfer` pays.
    pub fn slow_transfer_delay(&self) -> Duration {
        self.slow
    }
}

/// Render a caught panic payload (the `&str` / `String` cases; anything
/// else gets a placeholder). Shared by every `catch_unwind` boundary in
/// the stack so fault reports read the same everywhere.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// `catch_unwind` with the panic rendered to a `String` error — the
/// supervisor boundaries all want exactly this shape.
pub fn catch_panic<T>(f: impl FnOnce() -> T) -> Result<T, String> {
    std::panic::catch_unwind(AssertUnwindSafe(f)).map_err(|p| panic_message(&p))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_fire_at_exact_call_indices() {
        let plan = FaultPlan::events(&[
            (FaultSite::DecodeError, 0),
            (FaultSite::DecodeError, 2),
            (FaultSite::EnginePanic, 1),
        ]);
        assert!(plan.check(FaultSite::DecodeError), "call 0 fires");
        assert!(!plan.check(FaultSite::DecodeError), "call 1 silent");
        assert!(plan.check(FaultSite::DecodeError), "call 2 fires");
        assert!(!plan.check(FaultSite::DecodeError), "call 3 silent");
        assert!(!plan.check(FaultSite::EnginePanic), "independent counter");
        assert!(plan.check(FaultSite::EnginePanic));
        assert_eq!(plan.injected(), 3);
        assert_eq!(plan.fired(FaultSite::DecodeError), 2);
        assert_eq!(plan.calls(FaultSite::DecodeError), 4);
    }

    #[test]
    fn disabled_plan_never_fires() {
        let plan = FaultPlan::disabled();
        assert!(plan.is_disabled());
        for _ in 0..100 {
            for site in FaultSite::ALL {
                assert!(!plan.check(site));
            }
        }
        assert_eq!(plan.injected(), 0);
    }

    #[test]
    fn chaos_is_deterministic_per_seed() {
        let a = FaultPlan::chaos(7);
        let b = FaultPlan::chaos(7);
        for site in FaultSite::ALL {
            assert_eq!(a.sites[site.idx()].fire_at, b.sites[site.idx()].fire_at);
        }
        let c = FaultPlan::chaos(8);
        let differs = FaultSite::ALL
            .iter()
            .any(|s| a.sites[s.idx()].fire_at != c.sites[s.idx()].fire_at);
        assert!(differs, "different seeds should differ somewhere");
        assert!(!a.is_disabled(), "chaos schedules at least one fault");
    }

    #[test]
    fn catch_panic_renders_payloads() {
        assert_eq!(catch_panic(|| 5).unwrap(), 5);
        let e = catch_panic(|| panic!("boom {}", 1)).unwrap_err();
        assert_eq!(e, "boom 1");
    }
}
