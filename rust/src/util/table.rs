//! ASCII table rendering for eval drivers — prints the same rows the
//! paper's tables/figures report, plus CSV export for plotting.

/// A titled table of string cells.
#[derive(Debug, Clone, Default)]
pub struct Table {
    /// Title rendered above the table (empty = none).
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Data rows; every row has `headers.len()` cells.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header width).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render as an ASCII box table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {:<w$} |", c, w = w));
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Render as CSV (headers + rows, quoted where needed).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Print to stdout and optionally save CSV under `dir/name.csv`.
    pub fn emit(&self, dir: Option<&str>, name: &str) {
        print!("{}", self.render());
        if let Some(dir) = dir {
            let _ = std::fs::create_dir_all(dir);
            let path = format!("{}/{}.csv", dir, name);
            if std::fs::write(&path, self.to_csv()).is_ok() {
                println!("[saved {}]", path);
            }
        }
    }
}

/// Format a float with sensible precision for table cells.
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 100.0 {
        format!("{:.0}", x)
    } else if x.abs() >= 1.0 {
        format!("{:.2}", x)
    } else {
        format!("{:.4}", x)
    }
}

/// Format seconds human-readably (µs/ms/s).
pub fn ftime(secs: f64) -> String {
    if secs < 1e-3 {
        format!("{:.1}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.2}s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["method", "latency"]);
        t.row(vec!["freekv".into(), "1.2ms".into()]);
        t.row(vec!["arkvale-longname".into(), "13ms".into()]);
        let r = t.render();
        assert!(r.contains("| freekv           |"));
        assert!(r.lines().filter(|l| l.starts_with('+')).count() == 3);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        assert_eq!(t.to_csv(), "a,b\n\"x,y\",\"q\"\"z\"\n");
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn num_formats() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(1234.4), "1234");
        assert_eq!(fnum(3.14159), "3.14");
        assert_eq!(fnum(0.01234), "0.0123");
        assert_eq!(ftime(0.0000015), "1.5us");
        assert_eq!(ftime(0.0123), "12.30ms");
        assert_eq!(ftime(2.5), "2.50s");
    }
}
