//! Deterministic PRNG substrate (SplitMix64 core) — the offline build has
//! no `rand` crate. Provides uniform/normal/exponential/Poisson draws,
//! shuffles and categorical sampling for workload generation, the
//! attention oracle, and sampling from logits.

/// SplitMix64: tiny, fast, passes BigCrush when used as a 64-bit stream.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// cached second Box-Muller variate
    spare: Option<f64>,
}

impl Rng {
    /// RNG seeded deterministically from `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    /// Derive an independent stream (for per-head / per-request RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xBF58476D1CE4E5B9))
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare = Some(r * s);
        r * c
    }

    /// Normal draw with the given mean and standard deviation.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        (self.normal() as f32) * std + mean
    }

    /// Exponential with the given rate (mean 1/rate).
    pub fn exp(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }

    /// Poisson via inversion (small lambda) or normal approx (large).
    pub fn poisson(&mut self, lambda: f64) -> usize {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let n = self.normal() * lambda.sqrt() + lambda;
            return n.max(0.0).round() as usize;
        }
        let l = (-lambda).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample an index proportional to non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|w| w.max(0.0) as f64).sum();
        if total <= 0.0 {
            return self.below(weights.len().max(1));
        }
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w.max(0.0) as f64;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// k distinct indices from [0, n) (reservoir when k << n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut out: Vec<usize> = (0..k).collect();
        for i in k..n {
            let j = self.below(i + 1);
            if j < k {
                out[j] = i;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert_ne!(Rng::new(7).next_u64(), Rng::new(8).next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(1);
        let xs: Vec<f64> = (0..20_000).map(|_| r.f64()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {}", mean);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..40_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.05, "var {}", var);
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(3);
        for &lam in &[0.5, 4.0, 30.0, 200.0] {
            let n = 5000;
            let s: usize = (0..n).map(|_| r.poisson(lam)).sum();
            let mean = s as f64 / n as f64;
            assert!((mean - lam).abs() < lam.max(1.0) * 0.1, "lam {} mean {}", lam, mean);
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(4);
        let w = [0.0f32, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..8000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.4, "ratio {}", ratio);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(100, 10);
        assert_eq!(s.len(), 10);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 10);
        assert!(d.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
