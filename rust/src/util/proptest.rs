//! Micro property-testing harness (the `proptest` crate is not available
//! offline). Runs a property over many seeded random cases and reports
//! the failing seed; combined with `Rng::fork` this gives reproducible
//! shrink-free property tests for coordinator invariants.

use super::rng::Rng;

/// Run `prop` for `cases` seeded inputs; panics with the failing seed.
pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, cases: u64, mut prop: F) {
    for case in 0..cases {
        let seed = 0x5EED_0000u64 ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property `{}` failed on case {} (seed {:#x}): {}",
                name, case, seed, msg
            );
        }
    }
}

/// Assert helper returning Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_good_property() {
        check("sorted-after-sort", 50, |rng| {
            let mut v: Vec<u64> = (0..20).map(|_| rng.next_u64() % 100).collect();
            v.sort_unstable();
            prop_assert!(v.windows(2).all(|w| w[0] <= w[1]), "not sorted: {:?}", v);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn reports_failing_seed() {
        check("always-fails", 3, |_| Err("nope".into()));
    }
}
