//! Statistics substrate: summaries, percentiles, and fixed-bucket
//! latency histograms used by the serving metrics and the bench harness.

/// Summary statistics over a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// Median.
    pub p50: f64,
    /// 90th percentile.
    pub p90: f64,
    /// 99th percentile.
    pub p99: f64,
}

impl Summary {
    /// Summarize a sample (all-zero summary for empty input).
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0, p50: 0.0, p90: 0.0, p99: 0.0 };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (rank - lo as f64)
    }
}

/// Linear-interpolated percentile of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&s, p)
}

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Exponential-bucket histogram for latencies (µs granularity, ~4% error).
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

const HIST_BUCKETS: usize = 256;
const HIST_GROWTH: f64 = 1.08;
const HIST_BASE: f64 = 1e-6; // 1 µs

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Histogram { buckets: vec![0; HIST_BUCKETS], count: 0, sum: 0.0, min: f64::MAX, max: 0.0 }
    }

    fn bucket_of(v: f64) -> usize {
        if v <= HIST_BASE {
            return 0;
        }
        let idx = (v / HIST_BASE).ln() / HIST_GROWTH.ln();
        (idx as usize).min(HIST_BUCKETS - 1)
    }

    fn bucket_value(i: usize) -> f64 {
        HIST_BASE * HIST_GROWTH.powi(i as i32)
    }

    /// Record one observation.
    pub fn record(&mut self, v: f64) {
        self.buckets[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Observations recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean of recorded observations (exact, from the running sum).
    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum / self.count as f64 }
    }

    /// Approximate percentile (bucket resolution, clamped to min/max).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * self.count as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return Self::bucket_value(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Fold another histogram's observations into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-9);
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
    }

    #[test]
    fn histogram_percentiles_within_bucket_error() {
        let mut h = Histogram::new();
        for i in 1..=10_000 {
            h.record(i as f64 * 1e-5); // 10µs .. 100ms
        }
        let p50 = h.percentile(50.0);
        assert!((p50 - 0.05).abs() / 0.05 < 0.1, "p50 {}", p50);
        let p99 = h.percentile(99.0);
        assert!((p99 - 0.099).abs() / 0.099 < 0.1, "p99 {}", p99);
        assert_eq!(h.count(), 10_000);
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(0.001);
        b.record(0.1);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!(a.percentile(100.0) >= 0.09);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(Summary::of(&[]).n, 0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(Histogram::new().percentile(99.0), 0.0);
    }
}
