//! Minimal JSON parser/writer.
//!
//! The offline build environment vendors only the `xla` crate closure, so
//! serde/serde_json are unavailable; this module is the in-tree substrate
//! used for `artifacts/manifest.json`, golden traces, configs, and eval
//! result files. It supports the full JSON grammar (objects, arrays,
//! strings with escapes, numbers, bools, null) and preserves object key
//! order (insertion order) so emitted files diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (all numbers are f64).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Json>),
    /// Objects keep a side vector of keys in insertion order for stable
    /// serialization; lookups go through the map.
    Obj(JsonObj),
}

/// A JSON object preserving key insertion order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JsonObj {
    map: BTreeMap<String, Json>,
    order: Vec<String>,
}

impl JsonObj {
    /// Empty object.
    pub fn new() -> Self {
        Self::default()
    }
    /// Insert or replace a key (insertion order kept on replace).
    pub fn insert(&mut self, key: impl Into<String>, val: impl Into<Json>) {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.order.push(key.clone());
        }
        self.map.insert(key, val.into());
    }
    /// Value for `key`, if present.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }
    /// Keys in insertion order.
    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.order.iter()
    }
    /// Number of entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }
    /// Whether the object has no entries.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
    /// (key, value) pairs in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = (&String, &Json)> {
        self.order.iter().map(move |k| (k, &self.map[k]))
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}
impl From<JsonObj> for Json {
    fn from(o: JsonObj) -> Self {
        Json::Obj(o)
    }
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset in the input where parsing failed.
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}
impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing characters are an error).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    /// The number value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// The number value truncated to usize, if this is a `Num`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    /// The number value truncated to i64, if this is a `Num`.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    /// The boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The string value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The array elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// The object, if this is an `Obj`.
    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["a"]["b"]`-style access; returns Null for missing paths.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Array element `i`; Null when out of range or not an array.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ---- serialization ---------------------------------------------------

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s
    }

    /// Serialize without whitespace.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(lvl) = indent {
                        newline(out, lvl + 1);
                        v.write(out, Some(lvl + 1));
                    } else {
                        v.write(out, None);
                    }
                }
                if let Some(lvl) = indent {
                    newline(out, lvl);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(lvl) = indent {
                        newline(out, lvl + 1);
                        write_str(out, k);
                        out.push_str(": ");
                        v.write(out, Some(lvl + 1));
                    } else {
                        write_str(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let Some(lvl) = indent {
                    newline(out, lvl);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, level: usize) {
    out.push('\n');
    for _ in 0..level {
        out.push(' ');
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{}", n));
    } else {
        out.push_str("null"); // JSON has no inf/nan
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: parse the low half if present.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                self.i += 5;
                                if self.b.get(self.i) != Some(&b'\\')
                                    || self.b.get(self.i + 1) != Some(&b'u')
                                {
                                    return Err(self.err("lone high surrogate"));
                                }
                                let hex2 =
                                    std::str::from_utf8(&self.b[self.i + 2..self.i + 6])
                                        .map_err(|_| self.err("bad \\u escape"))?;
                                let lo = u32::from_str_radix(hex2, 16)
                                    .map_err(|_| self.err("bad \\u escape"))?;
                                self.i += 1; // consumed below with the +5
                                char::from_u32(
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00),
                                )
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("bad codepoint"))?);
                            self.i += 4; // the final +1 below covers 'u'
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(obj));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }
}

/// Convenience: array of numbers -> Vec<f64>.
pub fn num_array(j: &Json) -> Vec<f64> {
    j.as_arr()
        .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
        .unwrap_or_default()
}

/// Convenience: array of numbers -> Vec<usize>.
pub fn usize_array(j: &Json) -> Vec<usize> {
    j.as_arr()
        .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
        .unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.25e2").unwrap(), Json::Num(-325.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("a").idx(2).get("b").as_str(), Some("x"));
        assert_eq!(j.get("c").as_bool(), Some(false));
        assert_eq!(j.get("missing").as_str(), None);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"freekv","n":[1,2.5,-3],"nested":{"ok":true,"nul":null},"s":"q\"uote\\"}"#;
        let j = Json::parse(src).unwrap();
        let re = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, re);
        let re2 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, re2);
    }

    #[test]
    fn preserves_key_order() {
        let j = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = j.as_obj().unwrap().keys().cloned().collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn builder_api() {
        let mut o = JsonObj::new();
        o.insert("x", 1.5);
        o.insert("y", "s");
        o.insert("z", Json::Arr(vec![Json::from(1usize), Json::from(2usize)]));
        let j = Json::from(o);
        assert_eq!(
            Json::parse(&j.to_string_compact()).unwrap(),
            j
        );
    }
}
