//! Shutdown-signal plumbing for `freekv serve`, on raw libc symbols
//! (the offline build has no `signal-hook`/`ctrlc` crates; libc itself
//! is always linked on unix).
//!
//! Design: instead of an async-signal handler (whose safe vocabulary is
//! tiny), the process *blocks* SIGINT/SIGTERM up front —
//! [`block_shutdown_signals`] must run before other threads spawn so
//! they inherit the mask — and a dedicated watcher thread consumes them
//! synchronously with `sigwait` ([`watch_shutdown`]). On the first
//! signal the watcher flips the caller's flag and runs a wake closure
//! (the server pokes its own listener so a blocked `accept` notices);
//! ordinary Rust is legal there because it is a normal thread, not a
//! signal context. The woken server drains through its router seam, so
//! under `--replicas N` one signal drains the whole replica set on one
//! shared deadline ([`crate::coordinator::router::Router::drain`]). A
//! second signal hard-exits (130), so a wedged drain can still be
//! Ctrl-C'd away.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::{self, JoinHandle};

/// POSIX SIGINT (Ctrl-C).
pub const SIGINT: i32 = 2;
/// POSIX SIGTERM.
pub const SIGTERM: i32 = 15;
const SIG_BLOCK: i32 = 0;

/// `sigset_t` is 128 bytes on linux; sized generously for safety.
#[repr(C)]
#[derive(Clone, Copy)]
struct SigSet {
    _bits: [u64; 16],
}

extern "C" {
    fn sigemptyset(set: *mut SigSet) -> i32;
    fn sigaddset(set: *mut SigSet, sig: i32) -> i32;
    fn pthread_sigmask(how: i32, set: *const SigSet, old: *mut SigSet) -> i32;
    fn sigwait(set: *const SigSet, sig: *mut i32) -> i32;
}

fn shutdown_set() -> SigSet {
    let mut set = SigSet { _bits: [0; 16] };
    unsafe {
        sigemptyset(&mut set);
        sigaddset(&mut set, SIGINT);
        sigaddset(&mut set, SIGTERM);
    }
    set
}

/// Block SIGINT/SIGTERM in the calling thread. Call early in `main`,
/// before spawning the engine loop or the acceptor, so every later
/// thread inherits the mask and the watcher is the only consumer.
/// Returns false if the mask could not be installed.
pub fn block_shutdown_signals() -> bool {
    let set = shutdown_set();
    unsafe { pthread_sigmask(SIG_BLOCK, &set, std::ptr::null_mut()) == 0 }
}

/// Spawn the watcher thread: the first SIGINT/SIGTERM sets `flag`
/// (SeqCst) and runs `wake`; a second one exits the process (exit code
/// 130) so an operator can always get out.
pub fn watch_shutdown(
    flag: Arc<AtomicBool>,
    wake: impl Fn() + Send + 'static,
) -> JoinHandle<()> {
    thread::Builder::new()
        .name("freekv-signals".into())
        .spawn(move || {
            let set = shutdown_set();
            // belt and braces: mask these signals here too, in case the
            // caller forgot block_shutdown_signals (sigwait needs them
            // blocked in the waiting thread).
            unsafe { pthread_sigmask(SIG_BLOCK, &set, std::ptr::null_mut()) };
            let mut seen = 0u32;
            loop {
                let mut sig: i32 = 0;
                let rc = unsafe { sigwait(&set, &mut sig) };
                if rc != 0 {
                    // sigwait only fails on invalid sets; nothing to do
                    return;
                }
                seen += 1;
                if seen == 1 {
                    eprintln!("[freekv] caught signal {}; draining (again to force-quit)", sig);
                    flag.store(true, Ordering::SeqCst);
                    wake();
                } else {
                    eprintln!("[freekv] second signal; exiting immediately");
                    std::process::exit(130);
                }
            }
        })
        .expect("spawn signal watcher")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Duration;

    extern "C" {
        fn pthread_self() -> u64;
        fn pthread_kill(thread: u64, sig: i32) -> i32;
    }

    #[test]
    fn sigwait_thread_observes_a_directed_sigterm() {
        // Deliver SIGTERM to a thread that blocks it and sigwaits —
        // thread-directed via pthread_kill, so the rest of the test
        // process (which does not block SIGTERM) is never at risk.
        let flag = Arc::new(AtomicBool::new(false));
        let observed = flag.clone();
        let (tid_tx, tid_rx) = mpsc::channel::<u64>();
        let h = std::thread::spawn(move || {
            let set = shutdown_set();
            unsafe { pthread_sigmask(SIG_BLOCK, &set, std::ptr::null_mut()) };
            tid_tx.send(unsafe { pthread_self() }).unwrap();
            let mut sig: i32 = 0;
            let rc = unsafe { sigwait(&set, &mut sig) };
            assert_eq!(rc, 0, "sigwait failed");
            assert_eq!(sig, SIGTERM);
            observed.store(true, Ordering::SeqCst);
        });
        let tid = tid_rx.recv_timeout(Duration::from_secs(5)).expect("watcher started");
        let rc = unsafe { pthread_kill(tid, SIGTERM) };
        assert_eq!(rc, 0, "pthread_kill failed");
        h.join().expect("watcher thread exits cleanly");
        assert!(flag.load(Ordering::SeqCst));
    }
}
