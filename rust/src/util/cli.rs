//! Tiny CLI argument parser substrate (no `clap` in the offline registry).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments; used by the `freekv` binary and the examples.

use std::collections::HashMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: HashMap<String, String>,
    flags: Vec<String>,
    /// Arguments that are not `--` options, in order.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse an argument iterator (program name excluded).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.opts.insert(rest.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Value of `--key value` / `--key=value`, if given.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// String option with a default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// usize option with a default (default also on parse failure).
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// u64 option with a default (default also on parse failure).
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// f64 option with a default (default also on parse failure).
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Whether bare `--key` was given (no value attached).
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// First positional (the subcommand), if any.
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// Comma-separated list option.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn options_and_flags() {
        let a = parse("serve --model tiny --batch=4 --verbose --tau 0.9");
        assert_eq!(a.command(), Some("serve"));
        assert_eq!(a.get("model"), Some("tiny"));
        assert_eq!(a.usize_or("batch", 1), 4);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert!((a.f64_or("tau", 0.8) - 0.9).abs() < 1e-12);
    }

    #[test]
    fn defaults() {
        let a = parse("bench");
        assert_eq!(a.str_or("out", "results"), "results");
        assert_eq!(a.usize_or("n", 10), 10);
    }

    #[test]
    fn lists_and_positionals() {
        let a = parse("eval table2 --methods freekv,quest , --n 5");
        assert_eq!(a.positional, vec!["eval", "table2", ","]);
        assert_eq!(a.list_or("methods", &[]), vec!["freekv", "quest"]);
        assert_eq!(a.list_or("tasks", &["niah"]), vec!["niah"]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--fast --check --n 3");
        assert!(a.flag("fast") && a.flag("check"));
        assert_eq!(a.usize_or("n", 0), 3);
    }
}
