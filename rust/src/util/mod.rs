//! In-tree substrates: the offline build vendors only the `xla` crate
//! closure, so JSON, CLI parsing, PRNG, statistics, tables, and the
//! property-test harness are implemented here.

pub mod cli;
pub mod fault;
pub mod json;
pub mod proptest;
pub mod rng;
#[cfg(unix)]
pub mod signal;
pub mod stats;
pub mod sync;
pub mod table;

/// Split `total` items into `n` balanced contiguous widths (first
/// `total % n` get one extra). The one lane-partition rule shared by
/// the scheduler's split, the engine's bucket-aware lane planner, and
/// the latency model's lane twin — so they cannot drift apart.
/// `n` is clamped to `1..=total` (empty input yields a single 0 width).
pub fn balanced_widths(total: usize, n: usize) -> Vec<usize> {
    let n = n.clamp(1, total.max(1));
    let (w, rem) = (total / n, total % n);
    (0..n).map(|i| w + usize::from(i < rem)).collect()
}

#[cfg(test)]
mod tests {
    use super::balanced_widths;

    #[test]
    fn balanced_widths_cover_and_balance() {
        assert_eq!(balanced_widths(11, 3), vec![4, 4, 3]);
        assert_eq!(balanced_widths(8, 4), vec![2, 2, 2, 2]);
        assert_eq!(balanced_widths(3, 5), vec![1, 1, 1], "n clamps to total");
        assert_eq!(balanced_widths(5, 1), vec![5]);
        assert_eq!(balanced_widths(0, 2), vec![0]);
        for total in 1..40usize {
            for n in 1..8usize {
                let w = balanced_widths(total, n);
                assert_eq!(w.iter().sum::<usize>(), total);
                let (lo, hi) = (w.iter().min().unwrap(), w.iter().max().unwrap());
                assert!(hi - lo <= 1, "unbalanced {:?}", w);
            }
        }
    }
}
