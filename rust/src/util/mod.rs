//! In-tree substrates: the offline build vendors only the `xla` crate
//! closure, so JSON, CLI parsing, PRNG, statistics, tables, and the
//! property-test harness are implemented here.

pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
