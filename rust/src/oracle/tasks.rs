//! Task overlays for the attention oracle: the structures that make
//! NIAH / summarization / long-generation / reasoning traces behave like
//! their real counterparts.

use crate::util::rng::Rng;

/// Task category an oracle episode mimics (the paper's benchmark groups).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// Needle-in-a-haystack: one prompt page must be retrievable during
    /// the answer phase (last quarter of generation).
    Niah,
    /// Long-input QA / summarization: diffuse drifting interest over the
    /// whole prompt (LongBench-v2-like).
    Summarization,
    /// LongGenBench-like: periodic subtasks, each tied to a prompt page
    /// that must be surfaced during its window.
    LongGen,
    /// Reasoning (MATH/AIME/GPQA-like): fact pages are revisited after
    /// long cold stretches; revisits coincide with query-outlier jumps.
    Reasoning,
}

impl TaskKind {
    /// Lower-case task name (CLI / table rows).
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::Niah => "niah",
            TaskKind::Summarization => "summarization",
            TaskKind::LongGen => "longgen",
            TaskKind::Reasoning => "reasoning",
        }
    }

    /// Parse a task name as produced by [`TaskKind::name`] (plus aliases).
    pub fn parse(s: &str) -> Option<TaskKind> {
        Some(match s {
            "niah" => TaskKind::Niah,
            "summarization" | "summ" => TaskKind::Summarization,
            "longgen" => TaskKind::LongGen,
            "reasoning" => TaskKind::Reasoning,
            _ => return None,
        })
    }

    /// All task kinds, in table order.
    pub fn all() -> [TaskKind; 4] {
        [TaskKind::Niah, TaskKind::Summarization, TaskKind::LongGen, TaskKind::Reasoning]
    }
}

/// Shape of one oracle episode: task kind plus prompt/generation sizes.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    /// Task category.
    pub kind: TaskKind,
    /// Prompt length, in pages.
    pub prompt_pages: usize,
    /// Decode steps to generate.
    pub gen_steps: usize,
    /// decode steps per generated page (page granularity of the trace).
    pub tokens_per_page: usize,
}

impl TaskSpec {
    /// Spec from explicit sizes.
    pub fn new(kind: TaskKind, prompt_pages: usize, gen_steps: usize, tokens_per_page: usize) -> TaskSpec {
        TaskSpec { kind, prompt_pages, gen_steps, tokens_per_page }
    }

    /// Paper-flavoured defaults: long-input tasks have big prompts and
    /// short outputs; generation/reasoning tasks the reverse.
    pub fn default_for(kind: TaskKind) -> TaskSpec {
        match kind {
            TaskKind::Niah => TaskSpec::new(kind, 128, 80, 8),
            TaskKind::Summarization => TaskSpec::new(kind, 128, 200, 8),
            TaskKind::LongGen => TaskSpec::new(kind, 24, 640, 8),
            TaskKind::Reasoning => TaskSpec::new(kind, 32, 640, 8),
        }
    }
}

/// Precomputed per-episode schedule of required pages / jumps / boosts.
pub struct Overlay {
    kind: TaskKind,
    /// needle page (Niah).
    needle: usize,
    answer_start: usize,
    /// (page, hot_start, hot_end) windows.
    hot_windows: Vec<(usize, usize, usize)>,
    /// steps at which the overlay forces a query jump (reasoning revisits).
    jump_steps: Vec<usize>,
    boost_gain: f32,
}

impl Overlay {
    /// Draw an episode's schedule from the spec.
    pub fn new(spec: &TaskSpec, rng: &mut Rng) -> Overlay {
        let mut hot = Vec::new();
        let mut jumps = Vec::new();
        let (needle, answer_start, gain) = match spec.kind {
            TaskKind::Niah => {
                let needle = rng.below(spec.prompt_pages.max(1));
                (needle, spec.gen_steps * 3 / 5, 4.0)
            }
            TaskKind::Summarization => (0, spec.gen_steps, 0.0),
            TaskKind::LongGen => {
                // ~8 subtasks, each tied to a prompt page, hot for a window.
                let n_sub = 8.min(spec.prompt_pages);
                let span = spec.gen_steps / n_sub.max(1);
                for i in 0..n_sub {
                    let pg = rng.below(spec.prompt_pages.max(1));
                    let start = i * span + span / 4;
                    hot.push((pg, start, start + span / 2));
                }
                (0, spec.gen_steps, 3.0)
            }
            TaskKind::Reasoning => {
                // fact pages revisited after cold stretches; each revisit
                // forces a query jump (the Fig. 3c outliers).
                let n_facts = 6.min(spec.prompt_pages);
                let facts: Vec<usize> =
                    (0..n_facts).map(|_| rng.below(spec.prompt_pages.max(1))).collect();
                let mut t = spec.gen_steps / 8;
                while t + 30 < spec.gen_steps {
                    let pg = facts[rng.below(facts.len())];
                    hot.push((pg, t, t + 24));
                    jumps.push(t);
                    t += spec.gen_steps / 8 + rng.below(spec.gen_steps / 8 + 1);
                }
                (0, spec.gen_steps, 2.1)
            }
        };
        Overlay {
            kind: spec.kind,
            needle,
            answer_start,
            hot_windows: hot,
            jump_steps: jumps,
            boost_gain: gain,
        }
    }

    /// Pages the task needs covered at step t (for task scoring).
    pub fn required_pages(&self, t: usize, n_pages: usize) -> Vec<usize> {
        let mut req = Vec::new();
        if self.kind == TaskKind::Niah && t >= self.answer_start && self.needle < n_pages {
            req.push(self.needle);
        }
        for &(pg, s, e) in &self.hot_windows {
            if t >= s && t < e && pg < n_pages {
                req.push(pg);
            }
        }
        req
    }

    /// Force a query-latent jump at this step (reasoning revisits).
    pub fn forced_jump(&self, t: usize) -> bool {
        self.jump_steps.contains(&t)
    }

    /// Steer query latents toward required pages (the model "attends" to
    /// what the task needs).
    pub fn steer(&self, t: usize, q: &mut [f32], pages_emb: &[Vec<f32>]) {
        let mut any = false;
        for &(pg, s, e) in &self.hot_windows {
            if t >= s && t < e {
                for (qi, ei) in q.iter_mut().zip(&pages_emb[pg]) {
                    *qi += 0.9 * ei;
                }
                any = true;
            }
        }
        if self.kind == TaskKind::Niah && t >= self.answer_start {
            for (qi, ei) in q.iter_mut().zip(&pages_emb[self.needle]) {
                *qi += 1.2 * ei;
            }
            any = true;
        }
        let _ = any;
    }

    /// Raw-affinity boost for required pages.
    pub fn boost(&self, t: usize, aff: &mut [f32]) {
        if self.kind == TaskKind::LongGen && t % 24 < 2 {
            // periodic re-read of the instruction list: keeps subtask
            // pages warm enough that recency-based droppers retain them
            // (the paper notes RaaS holds up on LongGenBench).
            for &(pg, _, _) in &self.hot_windows {
                if pg < aff.len() {
                    aff[pg] += 1.4;
                }
            }
        }
        if self.kind == TaskKind::Niah && self.needle < aff.len() {
            // the question sits in the prompt, so the needle is mildly warm
            // from step 0 (this is what prefill-snapshot droppers latch on
            // to) and strongly hot in the answer phase.
            aff[self.needle] += if t >= self.answer_start { self.boost_gain } else { 1.6 };
        }
        for &(pg, s, e) in &self.hot_windows {
            if t >= s && t < e && pg < aff.len() {
                aff[pg] += self.boost_gain;
            }
        }
    }

    /// Summarization is diffuse: lower softmax temperature.
    pub fn beta_scale(&self, _t: usize) -> f32 {
        match self.kind {
            TaskKind::Summarization => 0.45,
            _ => 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn niah_requires_needle_only_in_answer_phase() {
        let spec = TaskSpec::default_for(TaskKind::Niah);
        let mut rng = Rng::new(1);
        let ov = Overlay::new(&spec, &mut rng);
        assert!(ov.required_pages(0, spec.prompt_pages).is_empty());
        let late = ov.required_pages(spec.gen_steps - 1, spec.prompt_pages);
        assert_eq!(late.len(), 1);
        assert!(late[0] < spec.prompt_pages);
    }

    #[test]
    fn reasoning_has_revisits_and_jumps() {
        let spec = TaskSpec::default_for(TaskKind::Reasoning);
        let mut rng = Rng::new(2);
        let ov = Overlay::new(&spec, &mut rng);
        assert!(!ov.jump_steps.is_empty());
        // revisit windows exist well after the start
        assert!(ov.hot_windows.iter().any(|&(_, s, _)| s > spec.gen_steps / 2));
    }

    #[test]
    fn longgen_subtasks_cover_timeline() {
        let spec = TaskSpec::default_for(TaskKind::LongGen);
        let mut rng = Rng::new(3);
        let ov = Overlay::new(&spec, &mut rng);
        assert!(ov.hot_windows.len() >= 4);
        let first = ov.hot_windows.first().unwrap().1;
        let last = ov.hot_windows.last().unwrap().1;
        assert!(last > first + spec.gen_steps / 2);
    }
}
