//! Attention-oracle simulator: synthetic per-head attention processes
//! with task-shaped dynamics, used for every accuracy experiment.
//!
//! Why this substitution is sound (DESIGN.md): what separates KV
//! dropping from KV retrieval from speculative retrieval is *which pages
//! a policy can still surface when token importance shifts* — a property
//! of the selection dynamics, not of natural language. The oracle
//! generates latent query/page processes whose statistics are calibrated
//! to the paper's measurements (mean adjacent-step query cosine ~0.85-0.92
//! with head-specific outlier steps, Fig. 3 / Table 8) and task overlays
//! matching the paper's categories:
//!   - NIAH: a needle page that must be retrievable at answer time;
//!   - summarization / long-input QA: diffuse, slowly drifting interest;
//!   - long-generation: periodic subtask pages (LongGenBench's structure);
//!   - reasoning: long generation with *revisits* — pages cold for a long
//!     stretch become hot again (the pattern that kills dropping).
//!
//! Policies only see what their real counterparts see: noisy summary
//! scores (current or previous step), realized attention over resident
//! pages, and the query-similarity signal. Metrics: attention-mass
//! recall and task scores (needle hit rate / completion rate / solved).

pub mod tasks;

use crate::util::rng::Rng;

pub use tasks::{TaskKind, TaskSpec};

/// Latent dimensionality of the query/page embedding process.
pub const LATENT: usize = 24;

/// Ground truth for one decode step.
#[derive(Debug, Clone)]
pub struct StepTruth {
    /// normalized true attention mass per (q-head, page): `[n_qo][pages]`.
    pub weights: Vec<Vec<f32>>,
    /// cos(q_i, q_{i-1}) per q-head (the correction signal).
    pub query_sim: Vec<f32>,
    /// noisy page-summary scores per (q-head, page) — what Quest-style
    /// selection sees at this step.
    pub summary_scores: Vec<Vec<f32>>,
    /// scores of the group-pooled query (Appendix B.2 MeanQ / MaxQ
    /// variants pool q *before* scoring): `[n_kv][pages]`.
    pub scores_meanq: Vec<Vec<f32>>,
    /// Scores of the group max-pooled query: `[n_kv][pages]`.
    pub scores_maxq: Vec<Vec<f32>>,
    /// pages that the task *requires* at this step (empty if none).
    pub required_pages: Vec<usize>,
    /// total pages existing at this step (prompt + generated so far).
    pub n_pages: usize,
}

/// The full generated trace of one episode.
pub struct Trace {
    /// Episode shape the trace was generated from.
    pub spec: TaskSpec,
    /// Query heads.
    pub n_qo: usize,
    /// KV heads.
    pub n_kv: usize,
    /// Per-step ground truth, in decode order.
    pub steps: Vec<StepTruth>,
}

impl Trace {
    /// Query heads per kv head (GQA group size).
    pub fn group(&self) -> usize {
        self.n_qo / self.n_kv
    }
}

/// Generator parameters (calibrated to the paper's similarity stats).
#[derive(Debug, Clone)]
pub struct OracleParams {
    /// AR(1) coefficient of the per-head latent — sets mean query
    /// similarity (~0.9 for alpha ~0.995 at LATENT=24).
    pub alpha: f32,
    /// per-step probability of a head-specific outlier jump (Fig. 3c).
    pub outlier_prob: f32,
    /// fraction of the latent redrawn on an outlier jump.
    pub outlier_mix: f32,
    /// within-group head noise (heads share the kv-head latent).
    pub head_noise: f32,
    /// summary approximation noise (page-summary score error).
    pub summary_noise: f32,
    /// softmax temperature over page affinities (low beta = diffuse).
    pub beta: f32,
}

impl Default for OracleParams {
    fn default() -> Self {
        OracleParams {
            alpha: 0.995,
            outlier_prob: 0.02,
            outlier_mix: 0.8,
            head_noise: 0.25,
            summary_noise: 0.35,
            beta: 2.2,
        }
    }
}

fn normalize(v: &mut [f32]) {
    let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-20);
    v.iter_mut().for_each(|x| *x /= n);
}

fn randn_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

/// Generate a trace for a task episode.
pub fn generate(spec: &TaskSpec, n_qo: usize, n_kv: usize, params: &OracleParams, seed: u64) -> Trace {
    let mut rng = Rng::new(seed ^ 0x0AC1E);
    let g = n_qo / n_kv;
    let max_pages = spec.prompt_pages + spec.gen_steps / spec.tokens_per_page + 2;

    // Fixed page embeddings.
    let pages_emb: Vec<Vec<f32>> = (0..max_pages)
        .map(|_| {
            let mut e = randn_vec(&mut rng, LATENT);
            normalize(&mut e);
            e
        })
        .collect();

    // Per-kv-head latent + per-q-head perturbations.
    let mut z_kv: Vec<Vec<f32>> = (0..n_kv)
        .map(|_| {
            let mut z = randn_vec(&mut rng, LATENT);
            normalize(&mut z);
            z
        })
        .collect();
    let mut head_eps: Vec<Vec<f32>> = (0..n_qo).map(|_| randn_vec(&mut rng, LATENT)).collect();
    let mut prev_q: Vec<Vec<f32>> = vec![vec![0.0; LATENT]; n_qo];
    let mut first = true;

    let overlay = tasks::Overlay::new(spec, &mut rng);
    let mut steps = Vec::with_capacity(spec.gen_steps);

    for t in 0..spec.gen_steps {
        let n_pages = (spec.prompt_pages + t / spec.tokens_per_page).min(max_pages);
        // Evolve kv-head latents; head-specific outliers.
        for m in 0..n_kv {
            let noise = randn_vec(&mut rng, LATENT);
            for (zi, ni) in z_kv[m].iter_mut().zip(&noise) {
                *zi = params.alpha * *zi + (1.0 - params.alpha * params.alpha).sqrt() * ni;
            }
            normalize(&mut z_kv[m]);
        }
        let mut outlier_heads = vec![false; n_qo];
        for h in 0..n_qo {
            // heads drift slightly within the group
            let noise = randn_vec(&mut rng, LATENT);
            for (ei, ni) in head_eps[h].iter_mut().zip(&noise) {
                *ei = 0.98 * *ei + 0.02f32.sqrt() * ni * 2.0;
            }
            if rng.f32() < params.outlier_prob || overlay.forced_jump(t) {
                outlier_heads[h] = true;
                let jump = randn_vec(&mut rng, LATENT);
                for (ei, ji) in head_eps[h].iter_mut().zip(&jump) {
                    *ei = (1.0 - params.outlier_mix) * *ei
                        + params.outlier_mix * ji * (1.0 + params.head_noise);
                }
            }
        }

        // Compose per-q-head query latents.
        let q: Vec<Vec<f32>> = (0..n_qo)
            .map(|h| {
                let m = h / g;
                let mut v: Vec<f32> = z_kv[m]
                    .iter()
                    .zip(&head_eps[h])
                    .map(|(z, e)| z + params.head_noise * e)
                    .collect();
                // task overlay steers the query toward required pages
                overlay.steer(t, &mut v, &pages_emb);
                normalize(&mut v);
                v
            })
            .collect();

        let query_sim: Vec<f32> = (0..n_qo)
            .map(|h| {
                if first {
                    1.0
                } else {
                    crate::linalg::dot(&q[h], &prev_q[h])
                }
            })
            .collect();

        // True attention mass + noisy summary scores per head.
        let beta = params.beta * overlay.beta_scale(t);
        let required = overlay.required_pages(t, n_pages);
        let mut weights = Vec::with_capacity(n_qo);
        let mut summary = Vec::with_capacity(n_qo);
        for qh in q.iter() {
            let mut aff: Vec<f32> = (0..n_pages)
                .map(|pg| crate::linalg::dot(qh, &pages_emb[pg]))
                .collect();
            overlay.boost(t, &mut aff);
            let mut w: Vec<f32> = aff.iter().map(|a| a * beta).collect();
            crate::linalg::softmax_inplace(&mut w);
            let est: Vec<f32> = aff
                .iter()
                .map(|a| {
                    // page-summary error: gaussian plus occasional heavy
                    // outliers (min/max bounds are loose for some pages)
                    let spike = if rng.f32() < 0.03 {
                        rng.normal_f32(0.0, 1.0) * 4.0 * params.summary_noise
                    } else {
                        0.0
                    };
                    a + params.summary_noise * rng.normal_f32(0.0, 1.0) + spike
                })
                .collect();
            weights.push(w);
            summary.push(est);
        }

        // Query-pooled variants (MeanQ / MaxQ): pool the group's query
        // latents first, score the pooled query once per kv head.
        let mut scores_meanq = Vec::with_capacity(n_kv);
        let mut scores_maxq = Vec::with_capacity(n_kv);
        for m in 0..n_kv {
            let grp = &q[m * g..(m + 1) * g];
            let mut qmean = vec![0.0f32; LATENT];
            let mut qmax = vec![f32::NEG_INFINITY; LATENT];
            for qh in grp {
                for i in 0..LATENT {
                    qmean[i] += qh[i] / g as f32;
                    qmax[i] = qmax[i].max(qh[i]);
                }
            }
            let score_of = |qv: &[f32], rng: &mut Rng| -> Vec<f32> {
                let mut aff: Vec<f32> =
                    (0..n_pages).map(|pg| crate::linalg::dot(qv, &pages_emb[pg])).collect();
                overlay.boost(t, &mut aff);
                aff.iter()
                    .map(|a| a + params.summary_noise * rng.normal_f32(0.0, 1.0))
                    .collect()
            };
            scores_meanq.push(score_of(&qmean, &mut rng));
            scores_maxq.push(score_of(&qmax, &mut rng));
        }

        steps.push(StepTruth {
            weights,
            query_sim,
            summary_scores: summary,
            scores_meanq,
            scores_maxq,
            required_pages: required,
            n_pages,
        });
        prev_q = q;
        first = false;
    }

    Trace { spec: spec.clone(), n_qo, n_kv, steps }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> TaskSpec {
        TaskSpec::new(TaskKind::Summarization, 64, 200, 8)
    }

    #[test]
    fn similarity_calibrated_to_paper() {
        let tr = generate(&spec(), 8, 2, &OracleParams::default(), 7);
        let mut sims = Vec::new();
        for st in tr.steps.iter().skip(1) {
            sims.extend(st.query_sim.iter().map(|&x| x as f64));
        }
        let mean = sims.iter().sum::<f64>() / sims.len() as f64;
        assert!((0.80..0.97).contains(&mean), "mean sim {}", mean);
        // outliers exist (Fig. 3c)
        let min = sims.iter().cloned().fold(f64::MAX, f64::min);
        assert!(min < 0.6, "min sim {}", min);
    }

    #[test]
    fn weights_normalized_and_groups_coherent() {
        let tr = generate(&spec(), 8, 2, &OracleParams::default(), 8);
        let st = &tr.steps[50];
        for w in &st.weights {
            let s: f32 = w.iter().sum();
            assert!((s - 1.0).abs() < 1e-3);
        }
        // heads in the same group agree more than heads across groups
        let top = |h: usize| crate::linalg::top_k(&st.weights[h], 8);
        let overlap = |a: &[usize], b: &[usize]| {
            a.iter().filter(|x| b.contains(x)).count()
        };
        let within = overlap(&top(0), &top(1)) + overlap(&top(2), &top(3));
        let across = overlap(&top(0), &top(5)) + overlap(&top(2), &top(7));
        assert!(within >= across, "within {} across {}", within, across);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&spec(), 4, 2, &OracleParams::default(), 42);
        let b = generate(&spec(), 4, 2, &OracleParams::default(), 42);
        assert_eq!(a.steps[10].weights, b.steps[10].weights);
        let c = generate(&spec(), 4, 2, &OracleParams::default(), 43);
        assert_ne!(a.steps[10].weights, c.steps[10].weights);
    }

    #[test]
    fn pages_grow_during_generation() {
        let tr = generate(&spec(), 4, 2, &OracleParams::default(), 1);
        assert!(tr.steps.last().unwrap().n_pages > tr.steps[0].n_pages);
    }
}
