//! Model / retrieval configuration, mirrored from the python compile path
//! via `artifacts/manifest.json` (plus hand-constructed paper geometries
//! for the latency simulator).

use crate::util::json::Json;

/// Geometry of a GQA transformer plus FreeKV paging parameters.
/// Field names match `python/compile/config.py::ModelConfig`.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    /// Config name, e.g. `"tiny"` or `"llama-3.1-8b"`.
    pub name: String,
    /// Number of transformer layers.
    pub n_layers: usize,
    /// Residual-stream width.
    pub d_model: usize,
    /// Number of query/output attention heads.
    pub n_qo: usize,
    /// Number of key/value heads (GQA: `n_kv <= n_qo`).
    pub n_kv: usize,
    /// Per-head dimension.
    pub d_head: usize,
    /// Feed-forward hidden width.
    pub d_ffn: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// RoPE base frequency.
    pub rope_theta: f64,
    /// RMSNorm epsilon.
    pub rms_eps: f64,
    /// Tokens per KV page. Also the stride of the prefix cache's
    /// boundary-hash chain, which the multi-replica router
    /// ([`crate::coordinator::router`]) reuses for prefix-affinity
    /// dispatch — replicas must agree on it for affinity to line up
    /// with what their retained tiers actually hold.
    pub page_size: usize,
    /// Maximum context length in tokens.
    pub max_context: usize,
    /// GPU-resident attention-sink pages (always attended).
    pub sink_pages: usize,
    /// GPU-resident local-window pages (most recent tokens).
    pub window_pages: usize,
    /// Pages recalled per step by speculative selection.
    pub select_pages: usize,
    /// bytes per element of the KV cache (4 = f32 on the CPU plugin;
    /// paper-geometry simulations use 2 = fp16).
    pub kv_elem_bytes: usize,
}

impl ModelConfig {
    /// Query heads per kv head (GQA group size).
    pub fn group_size(&self) -> usize {
        self.n_qo / self.n_kv
    }
    /// KV pages needed for a full `max_context` sequence (one layer).
    pub fn n_pages_max(&self) -> usize {
        self.max_context / self.page_size
    }
    /// Total GPU page budget: sink + window + selected.
    pub fn budget_pages(&self) -> usize {
        self.sink_pages + self.window_pages + self.select_pages
    }
    /// S: gathered token slots the decode attention kernel sees.
    pub fn budget_slots(&self) -> usize {
        self.budget_pages() * self.page_size
    }
    /// Bytes of one KV page for one kv head (K and V planes together).
    pub fn page_bytes_per_head(&self) -> usize {
        2 * self.page_size * self.d_head * self.kv_elem_bytes
    }
    /// Bytes of one full KV page across kv heads (K+V).
    pub fn page_bytes(&self) -> usize {
        self.n_kv * self.page_bytes_per_head()
    }
    /// Full-context KV bytes per layer.
    pub fn kv_bytes_per_layer(&self, context: usize) -> usize {
        2 * context * self.n_kv * self.d_head * self.kv_elem_bytes
    }

    /// Parse a config object from `artifacts/manifest.json`.
    pub fn from_json(j: &Json) -> anyhow::Result<ModelConfig> {
        let req = |k: &str| -> anyhow::Result<f64> {
            j.get(k).as_f64().ok_or_else(|| anyhow::anyhow!("manifest config missing `{}`", k))
        };
        Ok(ModelConfig {
            name: j.get("name").as_str().unwrap_or("?").to_string(),
            n_layers: req("n_layers")? as usize,
            d_model: req("d_model")? as usize,
            n_qo: req("n_qo")? as usize,
            n_kv: req("n_kv")? as usize,
            d_head: req("d_head")? as usize,
            d_ffn: req("d_ffn")? as usize,
            vocab: req("vocab")? as usize,
            rope_theta: req("rope_theta")?,
            rms_eps: req("rms_eps")?,
            page_size: req("page_size")? as usize,
            max_context: req("max_context")? as usize,
            sink_pages: req("sink_pages")? as usize,
            window_pages: req("window_pages")? as usize,
            select_pages: req("select_pages")? as usize,
            kv_elem_bytes: 4,
        })
    }

    // ----- paper geometries (for the latency simulator; fp16 KV) -----

    /// Llama-3.1-8B-Instruct: 32 layers, 32 q heads, 8 kv heads, d=128.
    pub fn llama31_8b() -> ModelConfig {
        ModelConfig {
            name: "llama-3.1-8b".into(),
            n_layers: 32,
            d_model: 4096,
            n_qo: 32,
            n_kv: 8,
            d_head: 128,
            d_ffn: 14336,
            vocab: 128256,
            rope_theta: 500000.0,
            rms_eps: 1e-5,
            page_size: 32,
            max_context: 131072,
            sink_pages: 16,   // S = 512 (paper efficiency setup)
            window_pages: 16, // W = 512
            select_pages: 32, // B = 2048 total budget
            kv_elem_bytes: 2,
        }
    }

    /// Qwen-2.5-7B-Instruct: 28 layers, 28 q heads, 4 kv heads, d=128.
    pub fn qwen25_7b() -> ModelConfig {
        ModelConfig {
            name: "qwen-2.5-7b".into(),
            n_layers: 28,
            d_model: 3584,
            n_qo: 28,
            n_kv: 4,
            d_head: 128,
            d_ffn: 18944,
            vocab: 152064,
            rope_theta: 1000000.0,
            rms_eps: 1e-6,
            page_size: 32,
            max_context: 131072,
            sink_pages: 16,
            window_pages: 16,
            select_pages: 32,
            kv_elem_bytes: 2,
        }
    }

    /// Qwen-2.5-14B-Instruct: 48 layers, 40 q heads, 8 kv heads, d=128.
    pub fn qwen25_14b() -> ModelConfig {
        ModelConfig {
            name: "qwen-2.5-14b".into(),
            n_layers: 48,
            d_model: 5120,
            n_qo: 40,
            n_kv: 8,
            d_head: 128,
            d_ffn: 13824,
            vocab: 152064,
            rope_theta: 1000000.0,
            rms_eps: 1e-5,
            page_size: 32,
            max_context: 131072,
            sink_pages: 16,
            window_pages: 16,
            select_pages: 32,
            kv_elem_bytes: 2,
        }
    }

    /// Look up a hand-constructed paper geometry by name.
    pub fn paper_geometry(name: &str) -> Option<ModelConfig> {
        match name {
            "llama-3.1-8b" => Some(Self::llama31_8b()),
            "qwen-2.5-7b" => Some(Self::qwen25_7b()),
            "qwen-2.5-14b" => Some(Self::qwen25_14b()),
            _ => None,
        }
    }
}

/// FreeKV algorithm parameters (paper §3 + Appendix A).
#[derive(Debug, Clone, PartialEq)]
pub struct FreeKvParams {
    /// Correction threshold tau: correction triggers when the
    /// group-pooled cos(q_i, q_{i-1}) drops below tau.
    pub tau: f32,
    /// Group pooling for the correction similarity: mean (paper) or max.
    pub correction_pool_max: bool,
    /// Selection variant (MeanS default, see Appendix B.2).
    pub variant: SelectVariant,
    /// Disable speculation entirely (tau = 1 equivalent fast path).
    pub no_speculation: bool,
    /// Dispatch speculative recall to the background worker so it
    /// overlaps the remaining layers' compute (§4.2). `false` keeps the
    /// serial in-thread dispatch as the ablation baseline; results are
    /// bit-identical either way.
    pub overlap: bool,
    /// Workers in the Send-safe PJRT executor pool
    /// (`runtime::executor`). With N >= 1, selection scoring is
    /// submitted to the pool and leaves the decode critical path,
    /// `Engine::decode_step_lanes` can pipeline N microbatch lanes
    /// across workers, and prefill runs as chunked pool jobs. `0` keeps
    /// every artifact execution inline on the engine thread — the
    /// serial-dispatch ablation baseline. Outputs are bit-identical
    /// either way (same artifacts, same inputs).
    ///
    /// Memory note: single-lane decode sends only selection (weight-free
    /// artifacts) to the pool, so workers stay cheap. Multi-lane decode
    /// routes weight-bearing artifacts too, but those are confined to
    /// the first `weight_workers` pool workers, so weight memory is
    /// `(weight_workers + 1) x` — it no longer grows with the pool.
    pub exec_workers: usize,
    /// Max decode microbatch lanes the engine keeps in flight
    /// concurrently (`Engine::decode_step_lanes`). The lane planner is
    /// bucket-aware: it only splits a batch into as many lanes as
    /// actually reduce padded artifact compute, so raising this past
    /// what the compiled buckets justify is harmless. `1` disables
    /// multi-lane pipelining entirely.
    pub max_lanes: usize,
    /// Pool workers allowed to hold a private copy of the model weights
    /// (clamped to `exec_workers`, min 1). Weight-bearing jobs (embed /
    /// QKV / attention / logits / prefill chunks) are routed only to
    /// these workers; weight-free selection scoring runs anywhere. This
    /// is the designated-weight-worker design: total weight memory is
    /// `(weight_workers + 1) x` (engine runtime + weight workers)
    /// instead of `(exec_workers + 1) x`.
    pub weight_workers: usize,
    /// Capacity of the shared CPU KV page pool, in pages aggregated
    /// across all layers (`--kv-pool-pages`). `0` = unbounded. With a
    /// capacity set, the scheduler charges each request's worst-case
    /// page footprint at admission and *queues* requests the pool
    /// cannot cover instead of letting decode OOM; pages free on
    /// finish/cancel and queued requests resume.
    pub kv_pool_pages: usize,
    /// Prefix-cache mode (`--prefix-cache[=resident|retained]`).
    /// `Resident`: copy-on-write sharing — a request whose token prefix
    /// hash-matches pages a resident request already committed aliases
    /// those pool pages (refcounted) instead of writing duplicates; a
    /// shared page is materialized privately before any write.
    /// `Retained` adds the persistent tier: a retiring request's
    /// committed pages stay adoptable (refcount 0, pinned by the
    /// cache) until evicted by pool pressure or `kv_retain_pages`, and
    /// new requests adopt their longest common prefix page by page.
    /// Off by default — with sharing off the pool is bit-identical to
    /// private per-request pools.
    pub prefix_cache: crate::kvcache::alloc::PrefixCacheMode,
    /// Max pages the retained prefix tier may pin
    /// (`--kv-retain-pages`). `0` = bounded only by pool pressure
    /// (`kv_pool_pages`). Ignored outside retained mode.
    pub kv_retain_pages: usize,
    /// Seed a deterministic fault-injection plan (`--chaos-seed`):
    /// injected job failures, worker deaths, slow transfers, and engine
    /// panics at seed-derived call indices, exercising the degradation
    /// ladders. `None` (production) compiles every fault site down to a
    /// single untaken branch.
    pub chaos_seed: Option<u64>,
    /// Element dtype of the shared CPU KV page pool (`--kv-dtype`):
    /// `f32` (bit-exact default), `int8` (symmetric, per-(head,plane)
    /// scales), or `int4` (packed). Quantize-on-offload, dequantize-on-
    /// gather; the GPU-resident sink + local window stay full
    /// precision. See `kvcache::quant`.
    pub kv_dtype: crate::kvcache::quant::KvDtype,
    /// Lock layout of the shared KV page allocator (`--kv-lock`):
    /// `sharded` (default) gives every layer slab its own lock so the
    /// recall worker and the engine stop serializing on the allocator;
    /// `global` funnels all layers through one lock — the contention
    /// baseline, bit-identical by construction. See `kvcache::alloc`.
    pub kv_lock: crate::kvcache::alloc::KvLockMode,
}

impl Default for FreeKvParams {
    fn default() -> Self {
        FreeKvParams {
            tau: 0.8,
            correction_pool_max: false,
            variant: SelectVariant::MeanS,
            no_speculation: false,
            overlap: true,
            exec_workers: 2,
            max_lanes: 2,
            weight_workers: 1,
            kv_pool_pages: 0,
            prefix_cache: crate::kvcache::alloc::PrefixCacheMode::Off,
            kv_retain_pages: 0,
            chaos_seed: None,
            kv_dtype: crate::kvcache::quant::KvDtype::F32,
            kv_lock: crate::kvcache::alloc::KvLockMode::Sharded,
        }
    }
}

/// Speculative page-selection scoring variant (paper Appendix B.2):
/// how per-page key summaries are pooled and which query is scored.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SelectVariant {
    /// Mean-pooled key summaries scored with the stale query (default).
    MeanS,
    /// Max-pooled key summaries scored with the stale query.
    MaxS,
    /// Mean-pooled summaries, per-group query-key scoring.
    MeanQK,
    /// Max-pooled summaries, per-group query-key scoring.
    MaxQK,
    /// Mean-pooled summaries scored with the current query.
    MeanQ,
    /// Max-pooled summaries scored with the current query.
    MaxQ,
}

impl SelectVariant {
    /// Canonical lowercase name (CLI / report key).
    pub fn as_str(&self) -> &'static str {
        match self {
            SelectVariant::MeanS => "means",
            SelectVariant::MaxS => "maxs",
            SelectVariant::MeanQK => "meanqk",
            SelectVariant::MaxQK => "maxqk",
            SelectVariant::MeanQ => "meanq",
            SelectVariant::MaxQ => "maxq",
        }
    }

    /// Parse the name produced by [`SelectVariant::as_str`].
    pub fn parse(s: &str) -> Option<SelectVariant> {
        Some(match s {
            "means" => SelectVariant::MeanS,
            "maxs" => SelectVariant::MaxS,
            "meanqk" => SelectVariant::MeanQK,
            "maxqk" => SelectVariant::MaxQK,
            "meanq" => SelectVariant::MeanQ,
            "maxq" => SelectVariant::MaxQ,
            _ => return None,
        })
    }

    /// All variants, in ablation-sweep order.
    pub fn all() -> [SelectVariant; 6] {
        [
            SelectVariant::MeanS,
            SelectVariant::MaxS,
            SelectVariant::MeanQK,
            SelectVariant::MaxQK,
            SelectVariant::MeanQ,
            SelectVariant::MaxQ,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_quantities() {
        let c = ModelConfig::llama31_8b();
        assert_eq!(c.group_size(), 4);
        assert_eq!(c.budget_pages(), 64);
        assert_eq!(c.budget_slots(), 2048); // paper budget B = 2048
        assert_eq!(c.page_bytes_per_head(), 2 * 32 * 128 * 2);
        assert_eq!(c.n_pages_max(), 4096);
    }

    #[test]
    fn from_json_roundtrip() {
        let src = r#"{
            "name": "tiny", "n_layers": 4, "d_model": 256, "n_qo": 8,
            "n_kv": 2, "d_head": 32, "d_ffn": 704, "vocab": 260,
            "rope_theta": 10000.0, "rms_eps": 1e-5, "page_size": 32,
            "max_context": 4096, "sink_pages": 2, "window_pages": 2,
            "select_pages": 12
        }"#;
        let c = ModelConfig::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(c.n_layers, 4);
        assert_eq!(c.group_size(), 4);
        assert_eq!(c.budget_slots(), 16 * 32);
        assert_eq!(c.kv_elem_bytes, 4);
    }

    #[test]
    fn from_json_missing_field_errors() {
        let c = ModelConfig::from_json(&Json::parse(r#"{"name":"x"}"#).unwrap());
        assert!(c.is_err());
    }

    #[test]
    fn variant_parse() {
        for v in SelectVariant::all() {
            assert_eq!(SelectVariant::parse(v.as_str()), Some(v));
        }
        assert_eq!(SelectVariant::parse("nope"), None);
    }
}
