//! FreeKV: boosting KV cache retrieval for efficient LLM inference.
//!
//! Three-layer reproduction: Pallas kernels (L1) + JAX model (L2) are
//! AOT-compiled to HLO text at build time; this crate is the Layer-3
//! rust coordinator that owns the serving runtime — request routing,
//! continuous batching, the paged KV cache with CPU offload (hybrid
//! NHD/GPU + HND/CPU layouts), double-buffered streamed recall, and the
//! FreeKV speculative-retrieval + fine-grained-correction policy.

pub mod config;
pub mod coordinator;
pub mod eval;
pub mod kvcache;
pub mod server;
pub mod oracle;
pub mod policies;
pub mod linalg;
pub mod runtime;
pub mod sim;
pub mod transfer;
pub mod util;
pub mod workload;
