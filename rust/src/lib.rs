// Stylistic clippy lints the codebase deliberately ignores: index-heavy
// tensor loops read better than iterator chains here, and the engine's
// geometry plumbing needs wide argument lists.
#![allow(
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::identity_op,
    clippy::many_single_char_names,
    clippy::type_complexity
)]
// Every public item carries rustdoc; CI builds the docs with
// `RUSTDOCFLAGS=-D warnings`, so a missing or broken doc fails there.
#![warn(missing_docs)]

//! FreeKV: boosting KV cache retrieval for efficient LLM inference.
//!
//! Three-layer reproduction: Pallas kernels (L1) + JAX model (L2) are
//! AOT-compiled to HLO text at build time; this crate is the Layer-3
//! rust coordinator that owns the serving runtime — an event-driven
//! session API (streaming tokens, cancellation, bounded admission) over
//! continuous batching, the paged KV cache with CPU offload (hybrid
//! NHD/GPU + HND/CPU layouts), double-buffered streamed recall, and the
//! FreeKV speculative-retrieval + fine-grained-correction policy.

pub mod config;
pub mod coordinator;
pub mod eval;
pub mod kvcache;
pub mod server;
pub mod oracle;
pub mod policies;
pub mod linalg;
pub mod runtime;
pub mod sim;
pub mod transfer;
pub mod util;
pub mod workload;
