"""Repo-root pytest config: make `pytest python/tests/ -q` work from the
repository root by putting the build-time python package on sys.path."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "python"))
