//! Quickstart: load the AOT artifacts, run one generation through the
//! FreeKV engine (speculative retrieval + correction), print the output
//! and the engine's retrieval statistics.
//!
//!   make artifacts && cargo run --release --example quickstart

use freekv::config::FreeKvParams;
use freekv::coordinator::engine::{Engine, SampleParams};
use freekv::coordinator::tokenizer;
use freekv::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::var("FREEKV_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let rt = Runtime::load(&artifacts)?;
    let mut eng = Engine::new(rt, "tiny", FreeKvParams { tau: 0.8, ..Default::default() })?;

    let prompt = "FreeKV is a training-free algorithm-system co-optimization framework \
                  that boosts KV cache retrieval for efficient LLM inference. ";
    let mut seq = eng.new_sequence(
        1,
        tokenizer::encode(prompt),
        48,
        SampleParams { temperature: 0.8, top_p: 0.95, seed: 42 },
    );
    seq.eos = Some(tokenizer::EOS);

    eng.generate(&mut seq)?;

    println!("prompt : {prompt}");
    println!("output : {:?}", tokenizer::decode(seq.generated()));
    println!();
    println!("steps           : {}", eng.stats.steps);
    println!("decode tok/s    : {:.1}", eng.stats.steps as f64 / eng.stats.decode_secs.max(1e-9));
    println!("corrections     : {} ({:.1}% of head-checks)", eng.stats.corrections, eng.stats.correction_rate() * 100.0);
    println!("recalled pages  : {}", eng.stats.recalled_pages);
    println!("offloaded pages : {}", seq.xfer.counters.offloaded_pages);
    println!("h2d chunks      : {} ({} bytes)", seq.xfer.counters.h2d_chunks, seq.xfer.counters.h2d_bytes);
    println!("gpu kv bytes    : {}", seq.kv.gpu_bytes());
    println!("cpu pool bytes  : {}", seq.kv.cpu_bytes());
    Ok(())
}
