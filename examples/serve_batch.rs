//! End-to-end serving driver (the repo's E2E validation run, recorded in
//! EXPERIMENTS.md): boots the full event-driven stack — PJRT runtime,
//! FreeKV engine, continuous-batching scheduler on its own engine
//! thread — submits a batch of concurrent sessions through the
//! `Submitter`, streams the first session's tokens as they are sampled,
//! and reports per-token latency/throughput percentiles.
//!
//!   make artifacts && cargo run --release --example serve_batch -- \
//!       --requests 12 --max-tokens 48 --max-batch 4

use freekv::config::FreeKvParams;
use freekv::coordinator::engine::{Engine, SampleParams};
use freekv::coordinator::engine_loop::{EngineLoop, LoopConfig, SessionEvent};
use freekv::coordinator::scheduler::{Request, Scheduler, SchedulerConfig};
use freekv::runtime::Runtime;
use freekv::util::cli::Args;

const PROMPTS: [&str; 6] = [
    "Summarize the key idea of speculative KV retrieval for long-context inference: ",
    "The hybrid NHD/HND layout eliminates fragmented PCIe transfers because ",
    "In grouped-query attention, selection must be group-consistent so that ",
    "Double-buffered streamed recall overlaps layout conversion with ",
    "Compared with KV dropping, retrieval preserves accuracy on reasoning since ",
    "A page summary stores the min and max key values so the Quest bound ",
];

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let artifacts = args.str_or("artifacts", "artifacts");
    let n_requests = args.usize_or("requests", 12);
    let max_tokens = args.usize_or("max-tokens", 48);
    let model = args.str_or("model", "tiny");
    let scfg = SchedulerConfig {
        max_batch: args.usize_or("max-batch", 4),
        admit_below: args.usize_or("admit-below", 4),
        ..Default::default()
    };

    let el = EngineLoop::spawn(LoopConfig::default(), move || {
        let rt = Runtime::load(&artifacts)?;
        let eng = Engine::new(rt, &model, FreeKvParams { tau: 0.9, ..Default::default() })?;
        Ok(Scheduler::new(eng, scfg))
    })?;
    let sub = el.submitter();

    println!("[serve_batch] requests={n_requests} max_tokens={max_tokens}");
    let t0 = std::time::Instant::now();
    let mut handles = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let text = PROMPTS[i % PROMPTS.len()];
        let mut req = Request::from_text(0, text, max_tokens);
        req.sample = SampleParams { temperature: 0.8, top_p: 0.95, seed: i as u64 };
        handles.push(sub.submit(req)?);
    }

    // Stream the first session token-by-token (the other sessions decode
    // in the same batches meanwhile), then collect the rest.
    let mut first = None;
    if let Some(h) = handles.first() {
        print!("req {:>2} streams: ", h.id());
        loop {
            match h.next_event() {
                Some(SessionEvent::Token { text, .. }) => print!("{}", text.escape_debug()),
                Some(SessionEvent::Done(c)) => {
                    println!();
                    first = Some(c);
                    break;
                }
                Some(SessionEvent::Error(e)) => anyhow::bail!("first session failed: {e}"),
                None => anyhow::bail!("engine loop died"),
            }
        }
    }
    let mut completions: Vec<_> = first.into_iter().collect();
    for h in handles.into_iter().skip(1) {
        completions.push(h.wait()?);
    }
    let wall = t0.elapsed().as_secs_f64();

    println!();
    for c in completions.iter().take(3) {
        let preview: String = c.text.chars().take(60).collect();
        println!("req {:>2} [{}]: {:?}", c.id, c.finish_reason.as_str(), preview);
    }
    println!("...");
    println!();
    println!("== serving metrics ==");
    println!("{}", sub.metrics_report()?);
    let tokens_out: usize = completions.iter().map(|c| c.generated_tokens).sum();
    println!("wall time       : {:.2}s", wall);
    println!(
        "goodput         : {:.1} generated tok/s over the whole run",
        tokens_out as f64 / wall
    );
    let st = sub.engine_stats()?;
    println!(
        "decode steps    : {} ({} batched, widest batch {})",
        st.steps, st.batched_steps, st.max_batch_lanes
    );
    println!("corrections     : {} ({:.1}%)", st.corrections, st.correction_rate() * 100.0);
    println!("recalled pages  : {}", st.recalled_pages);
    println!(
        "phase breakdown : qkv {:.2}s attn {:.2}s select {:.2}s gather {:.2}s recall {:.2}s logits {:.2}s",
        st.qkv_secs, st.attn_secs, st.select_secs, st.gather_secs, st.recall_secs, st.logits_secs
    );
    el.shutdown();
    Ok(())
}
