//! End-to-end serving driver (the repo's E2E validation run, recorded in
//! EXPERIMENTS.md): boots the full stack — PJRT runtime, FreeKV engine,
//! continuous-batching scheduler — feeds it a batched workload of real
//! requests, and reports latency/throughput percentiles.
//!
//!   make artifacts && cargo run --release --example serve_batch -- \
//!       --requests 12 --max-tokens 48 --max-batch 4

use freekv::config::FreeKvParams;
use freekv::coordinator::engine::{Engine, SampleParams};
use freekv::coordinator::scheduler::{Request, Scheduler, SchedulerConfig};
use freekv::runtime::Runtime;
use freekv::util::cli::Args;

const PROMPTS: [&str; 6] = [
    "Summarize the key idea of speculative KV retrieval for long-context inference: ",
    "The hybrid NHD/HND layout eliminates fragmented PCIe transfers because ",
    "In grouped-query attention, selection must be group-consistent so that ",
    "Double-buffered streamed recall overlaps layout conversion with ",
    "Compared with KV dropping, retrieval preserves accuracy on reasoning since ",
    "A page summary stores the min and max key values so the Quest bound ",
];

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let artifacts = args.str_or("artifacts", "artifacts");
    let n_requests = args.usize_or("requests", 12);
    let max_tokens = args.usize_or("max-tokens", 48);
    let model = args.str_or("model", "tiny");

    let rt = Runtime::load(&artifacts)?;
    let eng = Engine::new(rt, &model, FreeKvParams { tau: 0.9, ..Default::default() })?;
    let mut sched = Scheduler::new(
        eng,
        SchedulerConfig {
            max_batch: args.usize_or("max-batch", 4),
            admit_below: args.usize_or("admit-below", 4),
        },
    );

    println!("[serve_batch] model={model} requests={n_requests} max_tokens={max_tokens}");
    let t0 = std::time::Instant::now();
    for i in 0..n_requests {
        let text = PROMPTS[i % PROMPTS.len()];
        let mut req = Request::from_text(i as u64 + 1, text, max_tokens);
        req.sample = SampleParams { temperature: 0.8, top_p: 0.95, seed: i as u64 };
        sched.submit(req);
    }
    sched.drain()?;
    let wall = t0.elapsed().as_secs_f64();

    println!();
    for c in sched.completions.iter().take(3) {
        let preview: String = c.text.chars().take(60).collect();
        println!("req {:>2}: {:?}", c.id, preview);
    }
    println!("...");
    println!();
    println!("== serving metrics ==");
    println!("{}", sched.metrics.report());
    println!("wall time       : {:.2}s", wall);
    println!(
        "goodput         : {:.1} generated tok/s over the whole run",
        sched.metrics.tokens_out as f64 / wall
    );
    let st = &sched.engine.stats;
    println!("decode steps    : {} (batched)", st.steps);
    println!("corrections     : {} ({:.1}%)", st.corrections, st.correction_rate() * 100.0);
    println!("recalled pages  : {}", st.recalled_pages);
    println!(
        "phase breakdown : qkv {:.2}s attn {:.2}s select {:.2}s gather {:.2}s recall {:.2}s logits {:.2}s",
        st.qkv_secs, st.attn_secs, st.select_secs, st.gather_secs, st.recall_secs, st.logits_secs
    );
    Ok(())
}
