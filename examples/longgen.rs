//! Long-generation scenario (the paper's hardest case for KV dropping):
//! a short prompt followed by generation far past the GPU budget, so
//! pages continually complete, offload, and get speculatively recalled.
//! Reports correction rate, speculation hit rate, and the chunk-level
//! transfer profile under both CPU-pool layouts (the Fig. 9 HL ablation
//! on the *real* pipeline).
//!
//!   make artifacts && cargo run --release --example longgen -- --steps 256

use freekv::config::FreeKvParams;
use freekv::coordinator::engine::{Engine, SampleParams, Sequence};
use freekv::kvcache::Layout;
use freekv::runtime::Runtime;
use freekv::util::cli::Args;

fn run(layout: Layout, steps: usize, tau: f32, artifacts: &str) -> anyhow::Result<()> {
    let rt = Runtime::load(artifacts)?;
    let mut eng = Engine::new(rt, "tiny", FreeKvParams { tau, ..Default::default() })?;
    let prompt: Vec<i32> = (0..600).map(|i| (i * 31 % 250) as i32).collect();
    let mut seq = Sequence::new(
        1,
        &eng.cfg,
        prompt,
        steps,
        layout,
        SampleParams { temperature: 0.9, top_p: 0.95, seed: 17 },
    );
    let t0 = std::time::Instant::now();
    eng.generate(&mut seq)?;
    let wall = t0.elapsed().as_secs_f64();

    let st = &eng.stats;
    let c = &seq.xfer.counters;
    println!("== cpu pool layout: {:?} ==", layout);
    println!("generated        : {} tokens in {:.2}s ({:.1} tok/s)", steps, wall, steps as f64 / wall);
    println!("context at end   : {} tokens ({} pages)", seq.pos(), seq.pos() / eng.cfg.page_size);
    println!("corrections      : {} / {} checks ({:.1}%)", st.corrections, st.correction_checks, st.correction_rate() * 100.0);
    println!("speculative hits : {}", st.speculative_hits);
    println!("recalled pages   : {} ({:.2}/step)", st.recalled_pages, st.recalled_pages as f64 / st.steps.max(1) as f64);
    println!("offloaded pages  : {}", c.offloaded_pages);
    println!(
        "h2d transfers    : {} chunks, {} bytes ({} bytes/chunk avg) in {:.1}ms",
        c.h2d_chunks,
        c.h2d_bytes,
        c.h2d_bytes / c.h2d_chunks.max(1),
        c.real_h2d_secs * 1e3,
    );
    println!("convert time     : {:.1}ms", c.real_convert_secs * 1e3);
    println!();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 256);
    let tau = args.f64_or("tau", 0.9) as f32;
    let artifacts = args.str_or("artifacts", "artifacts");
    // HND (FreeKV's hybrid layout) vs NHD (mainstream layout) on the CPU
    // pool: same tokens, same recalls — compare bytes/chunk and wall time.
    run(Layout::Hnd, steps, tau, &artifacts)?;
    run(Layout::Nhd, steps, tau, &artifacts)?;
    Ok(())
}
