# Build entry points shared by developers and CI.
#
#   make artifacts   AOT-compile the JAX/Pallas model to HLO text +
#                    weights blob + golden trace under rust/artifacts/
#                    (needs python with jax[cpu]; see python/compile/).
#   make test        tier-1 verify (build + test, stub-friendly).
#   make bench       modeled-mode bench smoke; writes rust/BENCH_decode.json.

PYTHON ?= python3
ARTIFACTS_DIR ?= rust/artifacts

.PHONY: artifacts test bench clean-artifacts

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../$(ARTIFACTS_DIR)

test:
	cd rust && cargo build --release && cargo test -q

bench:
	cd rust && cargo bench --bench e2e

clean-artifacts:
	rm -rf $(ARTIFACTS_DIR)
