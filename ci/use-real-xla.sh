#!/usr/bin/env bash
# CI helper: swap the vendored `xla` stub for the real PJRT binding so
# artifact-driven tests execute on the XLA CPU plugin.
#
# The dev tree ships `rust/vendor/xla`, a compile-everywhere stub whose
# `PjRtClient::cpu()` fails at runtime; `runtime::client` was written
# against the real binding's surface (PjRtClient / HloModuleProto /
# XlaComputation / execute_b), so swapping the dependency needs no
# source changes in `freekv` (see the stub's module docs). This script:
#
#   1. rewrites the `xla` dependency in rust/Cargo.toml to the real
#      binding crate — PINNED by default to the immutable crates.io
#      release XLA_RS_VERSION, so the job is reproducible; exporting
#      XLA_RS_REV (a git rev/branch of XLA_RS_GIT) overrides the pin
#      for testing newer binding surfaces,
#   2. drops the stub from the workspace members,
#   3. fetches the prebuilt xla_extension archive the binding links
#      against and exports XLA_EXTENSION_DIR for subsequent steps.
#
# Intentionally CI-only: local offline builds keep the stub.

set -euo pipefail
cd "$(dirname "$0")/.."

XLA_RS_GIT="${XLA_RS_GIT:-https://github.com/LaurentMazare/xla-rs}"
# Default pin: the crates.io release of the binding (immutable, no
# floating SHA). Bump deliberately after validating on a real runner.
XLA_RS_VERSION="${XLA_RS_VERSION:-0.1.6}"
# Escape hatch: a git rev/branch of XLA_RS_GIT takes precedence over the
# crates.io pin when set (e.g. XLA_RS_REV=main to trial upstream).
XLA_RS_REV="${XLA_RS_REV:-}"
XLA_EXT_VERSION="${XLA_EXT_VERSION:-0.5.1}"
XLA_EXT_URL="${XLA_EXT_URL:-https://github.com/elixir-nx/xla/releases/download/v${XLA_EXT_VERSION}/xla_extension-x86_64-linux-gnu-cpu.tar.gz}"

if [ -n "${XLA_RS_REV}" ]; then
  echo "[use-real-xla] pointing rust/Cargo.toml at ${XLA_RS_GIT}@${XLA_RS_REV} (git override)"
else
  echo "[use-real-xla] pointing rust/Cargo.toml at crates.io xla =${XLA_RS_VERSION} (pinned)"
fi
python3 - "$XLA_RS_GIT" "$XLA_RS_REV" "$XLA_RS_VERSION" <<'EOF'
import re
import sys

git, rev, version = sys.argv[1], sys.argv[2], sys.argv[3]
path = "rust/Cargo.toml"
s = open(path).read()
if rev:
    dep = f'xla = {{ git = "{git}", rev = "{rev}" }}'
    if rev in ("main", "master"):
        dep = f'xla = {{ git = "{git}", branch = "{rev}" }}'
else:
    dep = f'xla = "={version}"'
s, n = re.subn(r'^xla = \{ path = "vendor/xla" \}$', dep, s, flags=re.M)
assert n == 1, "xla path dependency not found in rust/Cargo.toml"
s, n = re.subn(
    r'^members = \["vendor/anyhow", "vendor/xla"\]$',
    'members = ["vendor/anyhow"]',
    s,
    flags=re.M,
)
assert n == 1, "workspace members entry not found in rust/Cargo.toml"
open(path, "w").write(s)
print("[use-real-xla] rust/Cargo.toml rewritten")
EOF

ext_dir="${RUNNER_TEMP:-/tmp}/xla_extension"
if [ ! -d "${ext_dir}/xla_extension" ]; then
  echo "[use-real-xla] fetching ${XLA_EXT_URL}"
  mkdir -p "${ext_dir}"
  curl -fsSL "${XLA_EXT_URL}" | tar -xz -C "${ext_dir}"
fi

export XLA_EXTENSION_DIR="${ext_dir}/xla_extension"
echo "[use-real-xla] XLA_EXTENSION_DIR=${XLA_EXTENSION_DIR}"
# Propagate to later workflow steps (no-op outside GitHub Actions).
if [ -n "${GITHUB_ENV:-}" ]; then
  {
    echo "XLA_EXTENSION_DIR=${XLA_EXTENSION_DIR}"
    echo "LD_LIBRARY_PATH=${XLA_EXTENSION_DIR}/lib:${LD_LIBRARY_PATH:-}"
  } >> "$GITHUB_ENV"
fi
